//! Integration tests: the full pipeline through the public API, the CLI
//! surface, and cross-layer contracts that unit tests can't cover.
//!
//! These need built artifacts (`make artifacts`); they skip gracefully when
//! the directory is absent so `cargo test` stays green on a fresh clone.

use qera::budget::{allocate, profile, AllocStrategy, BudgetPlan, CandidateGrid};
use qera::coordinator::{
    calibrate, quantize, quantize_streaming, quantize_streaming_with, CalibResult,
    PipelineConfig, StreamOptions,
};
use qera::data::Corpus;
use qera::linalg::Mat64;
use qera::model::{init::init_params, Checkpoint, ModelSpec, QuantCheckpoint};
use qera::quant::QFormat;
use qera::runtime::Registry;
use qera::solver::{expected_output_error, Method, PsdBackend, SvdBackend};
use qera::util::rng::Rng;
use std::path::PathBuf;

fn registry() -> Option<Registry> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
}

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join("qera_integration");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn randomized_svd_backend_tracks_exact_on_nano() {
    // Acceptance check for the rank-aware solver fast path: on the nano
    // checkpoint the randomized backend must keep the expected layer output
    // error (Tr(R P Pᵀ), the paper's Problem-2 objective) within 1e-2
    // relative of the exact backend, per method, aggregated over layers.
    // Runs without PJRT artifacts: calibration statistics are synthetic.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(7)));
    let calib = CalibResult::synthetic(&spec, 256, 11);
    let fmt = QFormat::Mxint { bits: 3, block: 32 };
    let rank = 8; // rank * 4 <= 64 = min layer dim -> randomized engages
    let sites = spec.linear_sites();

    for method in [Method::QeraExact, Method::QeraApprox] {
        let exact = quantize(
            &ckpt,
            &PipelineConfig::new(method, fmt, rank).with_svd(SvdBackend::Exact),
            Some(&calib),
        )
        .unwrap();
        let rand = quantize(
            &ckpt,
            &PipelineConfig::new(method, fmt, rank).with_svd(SvdBackend::Randomized {
                oversample: SvdBackend::DEFAULT_OVERSAMPLE,
                power_iters: SvdBackend::DEFAULT_POWER_ITERS,
            }),
            Some(&calib),
        )
        .unwrap();

        let mut total_exact = 0.0f64;
        let mut total_rand = 0.0f64;
        for site in &sites {
            let rxx = calib.for_site(site).rxx_mean().unwrap();
            let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
            let p_exact = Mat64::from_tensor(&exact.merged[site.param_idx]).sub(&w);
            let p_rand = Mat64::from_tensor(&rand.merged[site.param_idx]).sub(&w);
            let e_exact = expected_output_error(&p_exact, &rxx);
            let e_rand = expected_output_error(&p_rand, &rxx);
            // per-site sanity: no catastrophic divergence
            assert!(
                (e_rand - e_exact).abs() <= 5e-2 * e_exact.max(1e-12),
                "{} {}: rand {e_rand} vs exact {e_exact}",
                method.name(),
                site.name
            );
            total_exact += e_exact;
            total_rand += e_rand;
        }
        // the acceptance bound: within 1e-2 relative, model-wide
        assert!(
            (total_rand - total_exact).abs() <= 1e-2 * total_exact,
            "{}: rand {total_rand} vs exact {total_exact}",
            method.name()
        );
    }
}

#[test]
fn lowrank_psd_backend_tracks_exact_on_nano() {
    // Acceptance check for the low-rank whitening fast path: on the nano
    // checkpoint, qera-exact solved with the low-rank + diagonal
    // `(R^{1/2}, R^{-1/2})` split must keep the expected layer output error
    // (Tr(R P Pᵀ), the paper's Problem-2 objective) within 1e-2 relative of
    // the exact eigendecomposition, aggregated over layers.  rank_mult 2
    // keeps the split genuinely approximate on nano's 64-wide layers
    // (k = 16 < 64); the exact SVD isolates the psd backend's effect.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(13)));
    let calib = CalibResult::synthetic(&spec, 256, 11);
    let fmt = QFormat::Mxint { bits: 3, block: 32 };
    let rank = 8;
    let sites = spec.linear_sites();

    let exact = quantize(
        &ckpt,
        &PipelineConfig::new(Method::QeraExact, fmt, rank)
            .with_svd(SvdBackend::Exact)
            .with_psd(PsdBackend::Exact),
        Some(&calib),
    )
    .unwrap();
    let low = quantize(
        &ckpt,
        &PipelineConfig::new(Method::QeraExact, fmt, rank)
            .with_svd(SvdBackend::Exact)
            .with_psd(PsdBackend::LowRank {
                rank_mult: 2,
                power_iters: PsdBackend::DEFAULT_POWER_ITERS,
            }),
        Some(&calib),
    )
    .unwrap();

    let mut total_exact = 0.0f64;
    let mut total_low = 0.0f64;
    for site in &sites {
        let rxx = calib.for_site(site).rxx_mean().unwrap();
        let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
        let p_exact = Mat64::from_tensor(&exact.merged[site.param_idx]).sub(&w);
        let p_low = Mat64::from_tensor(&low.merged[site.param_idx]).sub(&w);
        total_exact += expected_output_error(&p_exact, &rxx);
        total_low += expected_output_error(&p_low, &rxx);
    }
    // per-layer exact is the Problem-2 optimum, so low-rank can only lose
    // (1e-6 margin: merged weights round through f32, ~1e-7 relative noise)
    assert!(total_low >= total_exact * (1.0 - 1e-6), "low-rank beat the optimum?");
    // the acceptance bound: within 1e-2 relative, model-wide
    assert!(
        (total_low - total_exact).abs() <= 1e-2 * total_exact,
        "lowrank {total_low} vs exact {total_exact}"
    );

    // and the low-rank pipeline stays deterministic
    let again = quantize(
        &ckpt,
        &PipelineConfig::new(Method::QeraExact, fmt, rank)
            .with_svd(SvdBackend::Exact)
            .with_psd(PsdBackend::LowRank {
                rank_mult: 2,
                power_iters: PsdBackend::DEFAULT_POWER_ITERS,
            }),
        Some(&calib),
    )
    .unwrap();
    for (x, y) in low.merged.iter().zip(&again.merged) {
        assert_eq!(x, y);
    }
}

#[test]
fn randomized_backend_pipeline_is_deterministic() {
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(9)));
    let cfg = PipelineConfig::new(Method::ZeroQuantV2, QFormat::Mxint { bits: 3, block: 32 }, 8)
        .with_svd(SvdBackend::Randomized {
            oversample: SvdBackend::DEFAULT_OVERSAMPLE,
            power_iters: SvdBackend::DEFAULT_POWER_ITERS,
        });
    let a = quantize(&ckpt, &cfg, None).unwrap();
    let b = quantize(&ckpt, &cfg, None).unwrap();
    for (x, y) in a.merged.iter().zip(&b.merged) {
        assert_eq!(x, y);
    }
    assert!(a.solve_ms_total > 0.0);
}

#[test]
fn budget_plans_beat_uniform_at_matched_bits() {
    // Acceptance check for the budget allocator (PR 5): on the nano PTQ
    // setup, the greedy and Lagrangian plans must achieve strictly lower
    // total predicted output error than the uniform plan at the same
    // bits/weight budget, and the executed pipeline must realize exactly
    // the error and bits the plan predicted (same seeds, same solves).
    // Runs without PJRT artifacts: calibration statistics are synthetic.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(21)));
    let calib = CalibResult::synthetic(&spec, 256, 22);
    let base = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 4, block: 32 }, 8);
    let prof = profile(&ckpt, &calib, &base, &CandidateGrid::default_ptq()).unwrap();
    let budget = 3.75;

    let uni = allocate(&prof, budget, AllocStrategy::Uniform).unwrap();
    let gre = allocate(&prof, budget, AllocStrategy::Greedy).unwrap();
    let lag = allocate(&prof, budget, AllocStrategy::Lagrangian).unwrap();
    for plan in [&uni, &gre, &lag] {
        assert!(
            plan.achieved_bits <= budget + 1e-9,
            "{}: {} > {budget}",
            plan.strategy.name(),
            plan.achieved_bits
        );
    }
    // the acceptance bound: non-uniform spending strictly wins
    assert!(
        gre.total_error < uni.total_error,
        "greedy {} !< uniform {}",
        gre.total_error,
        uni.total_error
    );
    assert!(
        lag.total_error <= uni.total_error + 1e-12,
        "lagrangian {} > uniform {}",
        lag.total_error,
        uni.total_error
    );

    // executing the greedy plan realizes the predicted error and bits:
    // the profiler solves with the pipeline's own per-site seeds
    let qm = quantize(&ckpt, &base.clone().with_plan(gre.clone()), Some(&calib)).unwrap();
    assert!(
        (qm.effective_bits() - gre.achieved_bits).abs() < 1e-9,
        "{} vs {}",
        qm.effective_bits(),
        gre.achieved_bits
    );
    let sites = spec.linear_sites();
    let mut realized = 0.0f64;
    for site in &sites {
        let rxx = calib.for_site(site).rxx_mean().unwrap();
        let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
        let p = Mat64::from_tensor(&qm.merged[site.param_idx]).sub(&w);
        realized += expected_output_error(&p, &rxx);
    }
    assert!(
        (realized - gre.total_error).abs() <= 1e-6 * gre.total_error.max(1e-12),
        "realized {realized} vs predicted {}",
        gre.total_error
    );

    // ... and strictly beats the executed uniform plan on the same metric
    let qm_uni = quantize(&ckpt, &base.clone().with_plan(uni.clone()), Some(&calib)).unwrap();
    let mut realized_uni = 0.0f64;
    for site in &sites {
        let rxx = calib.for_site(site).rxx_mean().unwrap();
        let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
        let p = Mat64::from_tensor(&qm_uni.merged[site.param_idx]).sub(&w);
        realized_uni += expected_output_error(&p, &rxx);
    }
    assert!(realized < realized_uni, "{realized} !< {realized_uni}");
}

#[test]
fn budget_plan_artifact_reproduces_identical_checkpoint() {
    // Acceptance check for the plan round trip: --plan-out then --plan-in
    // must reproduce the identical quantized checkpoint.  The JSON form
    // prints shortest-round-trip f64s, so the reloaded plan is equal and
    // the re-executed pipeline is bit-identical.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(23)));
    let calib = CalibResult::synthetic(&spec, 192, 24);
    let base = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 8);
    let prof = profile(&ckpt, &calib, &base, &CandidateGrid::default_ptq()).unwrap();
    let plan = allocate(&prof, 3.5, AllocStrategy::Greedy).unwrap();

    let path = tmpdir().join("nano-plan.json");
    plan.save(&path).unwrap();
    let reloaded = BudgetPlan::load(&path).unwrap();
    assert_eq!(reloaded, plan);

    let a = quantize(&ckpt, &base.clone().with_plan(plan), Some(&calib)).unwrap();
    let b = quantize(&ckpt, &base.clone().with_plan(reloaded), Some(&calib)).unwrap();
    for (x, y) in a.merged.iter().zip(&b.merged) {
        assert_eq!(x, y);
    }
    assert_eq!(a.ckpt.payload_bytes(), b.ckpt.payload_bytes());

    // the packed on-disk form round-trips too
    let qpath = tmpdir().join("nano-plan.qqkpt");
    a.ckpt.save(&qpath).unwrap();
    let back = QuantCheckpoint::load(&qpath).unwrap();
    assert_eq!(back.materialize_merged(), a.merged);
}

#[test]
fn full_ptq_pipeline_roundtrip() {
    let Some(reg) = registry() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(0)));
    let corpus = Corpus::generate(spec.vocab, 20_000, 1);

    // calibrate -> quantize -> save -> load -> evaluate == in-memory result
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 4, true).unwrap();
    let cfg = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 8);
    let qm = quantize(&ckpt, &cfg, Some(&calib)).unwrap();

    let path = tmpdir().join("pipeline.qqkpt");
    qm.ckpt.save(&path).unwrap();
    let back = QuantCheckpoint::load(&path).unwrap();
    assert_eq!(back.materialize_merged(), qm.merged);

    let ppl_mem = qera::eval::perplexity(&reg, &spec, &qm.merged, &corpus, 2).unwrap();
    let ppl_disk =
        qera::eval::perplexity(&reg, &spec, &back.materialize_merged(), &corpus, 2).unwrap();
    assert_eq!(ppl_mem, ppl_disk);
}

#[test]
fn quantized_model_output_error_ordering() {
    // end-to-end statement of the paper's core claim on the real model
    // forward: output error (logit MSE) orders w-only > zeroquant >= qera
    let Some(reg) = registry() else {
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(3)));
    let corpus = Corpus::generate(spec.vocab, 30_000, 4);
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 8, true).unwrap();
    let fmt = QFormat::Mxint { bits: 2, block: 16 };

    let err_of = |method: Method, rank: usize| -> f64 {
        let qm = quantize(&ckpt, &PipelineConfig::new(method, fmt, rank), Some(&calib)).unwrap();
        qera::eval::model_output_error(&reg, &spec, &ckpt.params, &qm.merged, &corpus, 3)
            .unwrap()
    };
    let e_wonly = err_of(Method::WOnly, 0);
    let e_zq = err_of(Method::ZeroQuantV2, 16);
    let e_approx = err_of(Method::QeraApprox, 16);
    let e_exact = err_of(Method::QeraExact, 16);
    assert!(e_zq < e_wonly, "zq {e_zq} !< w-only {e_wonly}");
    // qera should beat plain SVD on *output* error (the theorem's claim,
    // allowing a sliver of slack for finite calibration + nonlinear layers)
    assert!(e_approx < e_zq * 1.05, "approx {e_approx} vs zq {e_zq}");
    assert!(e_exact < e_zq * 1.05, "exact {e_exact} vs zq {e_zq}");
}

#[test]
fn cli_pretrain_quantize_eval() {
    let Some(_reg) = registry() else {
        return;
    };
    let dir = tmpdir();
    let ckpt_path = dir.join("cli.qkpt").to_string_lossy().to_string();
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let art = art.to_string_lossy().to_string();

    let run = |args: &[&str]| {
        let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        qera::cli::main_with_args(&argv)
    };
    run(&[
        "pretrain",
        "--artifacts",
        &art,
        "--model",
        "nano",
        "--pretrain-steps",
        "20",
        "--corpus-tokens",
        "30000",
        "--out",
        &ckpt_path,
    ])
    .unwrap();
    assert!(PathBuf::from(&ckpt_path).exists());

    let q_path = dir.join("cli.qqkpt").to_string_lossy().to_string();
    run(&[
        "quantize",
        "--artifacts",
        &art,
        "--ckpt",
        &ckpt_path,
        "--method",
        "qera-approx",
        "--format",
        "mxint4:32",
        "--rank",
        "4",
        "--calib-batches",
        "2",
        "--corpus-tokens",
        "30000",
        "--out",
        &q_path,
    ])
    .unwrap();
    assert!(PathBuf::from(&q_path).exists());

    run(&[
        "eval-ppl",
        "--artifacts",
        &art,
        "--qckpt",
        &q_path,
        "--corpus-tokens",
        "30000",
        "--eval-batches",
        "2",
    ])
    .unwrap();

    // unknown command / bad flags fail cleanly
    assert!(run(&["frobnicate"]).is_err());
    assert!(run(&["quantize", "--artifacts", &art]).is_err());
}

#[test]
fn cli_native_eval_and_serve_without_artifacts() {
    // the --exec native path needs no xla artifacts: build a quantized nano
    // checkpoint in-process, then drive eval-ppl and serve through the CLI
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(21)));
    let cfg = PipelineConfig::new(Method::WOnly, QFormat::Mxint { bits: 4, block: 32 }, 0);
    let qm = quantize(&ckpt, &cfg, None).unwrap();

    let dir = tmpdir();
    let q_path = dir.join("native.qqkpt").to_string_lossy().to_string();
    qm.ckpt.save(&q_path).unwrap();

    let run = |args: &[&str]| {
        let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        qera::cli::main_with_args(&argv)
    };
    // point --artifacts at a dir with no manifest: native must not open it
    let bogus = dir.join("no-artifacts-here").to_string_lossy().to_string();
    for _ in 0..2 {
        // reproducible: identical output both runs (same corpus seed)
        run(&[
            "eval-ppl",
            "--artifacts",
            &bogus,
            "--qckpt",
            &q_path,
            "--exec",
            "native",
            "--corpus-tokens",
            "30000",
            "--eval-batches",
            "2",
        ])
        .unwrap();
    }
    run(&[
        "serve",
        "--artifacts",
        &bogus,
        "--qckpt",
        &q_path,
        "--exec",
        "native",
        "--prompts",
        "3",
        "--new-tokens",
        "4",
    ])
    .unwrap();
    // and the flag rejects unknown backends
    assert!(run(&["eval-ppl", "--qckpt", &q_path, "--exec", "tpu"]).is_err());
}

// ------------------------------------------------- sharded checkpoints

/// A synthetic deep model: narrow layers so the test is fast, with depth as
/// the only variable — exactly what the bounded-memory claim quantifies.
fn deep_spec(n_layers: usize) -> ModelSpec {
    ModelSpec {
        name: format!("deep{n_layers}"),
        vocab: 64,
        d_model: 32,
        n_layers,
        n_heads: 2,
        d_ff: 64,
        seq: 16,
        batch: 2,
        n_classes: 2,
    }
}

#[test]
fn streaming_quantization_peak_memory_is_depth_independent() {
    // ISSUE acceptance: the streaming pipeline (load shard -> solve ->
    // pack -> write -> drop) must keep peak live tensor bytes bounded by a
    // constant number of layer groups, independent of total depth.  A 4x
    // deeper model may not even double the peak (in practice it is flat).
    let dir = tmpdir();
    let cfg = PipelineConfig::new(Method::WOnly, QFormat::Mxint { bits: 4, block: 32 }, 0);
    let peak_of = |n_layers: usize| -> (usize, usize) {
        let spec = deep_spec(n_layers);
        let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(5)));
        let total_f32_bytes = spec.n_params() * 4;
        let src = dir.join(format!("deep{n_layers}.qkpt"));
        ckpt.save(&src).unwrap();
        let out = dir.join(format!("deep{n_layers}-q.manifest.json"));
        let sum = quantize_streaming(&src, &cfg, None, &out, 1).unwrap();
        // head group + one group per layer + tail group
        assert_eq!(sum.n_shards, n_layers + 2);
        assert!(sum.peak_live_bytes > 0);
        // the output round-trips through the reader API
        let back = qera::model::open(&out).unwrap().into_quant().unwrap();
        assert_eq!(back.spec, spec);
        (sum.peak_live_bytes, total_f32_bytes)
    };
    let (peak8, _) = peak_of(8);
    let (peak32, total32) = peak_of(32);
    assert!(
        peak32 < 2 * peak8,
        "peak live bytes grew with depth: {peak32} at 32 layers vs {peak8} at 8"
    );
    // and the peak is a small fraction of the full dense model
    assert!(
        peak32 * 2 < total32,
        "peak {peak32} not bounded below the {total32}-byte dense model"
    );
}

#[test]
fn cli_shard_layers_streams_and_native_consumers_read_manifests() {
    // no artifacts anywhere: quantize --shard-layers writes a sharded
    // manifest through the streaming pipeline, and eval-ppl / serve /
    // assumption consume it with --exec native
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(51)));
    let dir = tmpdir();
    let src = dir.join("shard-src.qkpt").to_string_lossy().to_string();
    ckpt.save(&src).unwrap();
    let out = dir.join("shard-q.manifest.json").to_string_lossy().to_string();

    let run = |args: &[&str]| {
        let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        qera::cli::main_with_args(&argv)
    };
    run(&[
        "quantize",
        "--ckpt",
        &src,
        "--method",
        "w-only",
        "--format",
        "mxint4:32",
        "--rank",
        "0",
        "--shard-layers",
        "1",
        "--out",
        &out,
        "--corpus-tokens",
        "30000",
    ])
    .unwrap();
    let reader = qera::model::open(&out).unwrap();
    assert!(reader.is_sharded());
    assert_eq!(reader.n_shards(), spec.n_layers + 2);

    let bogus = dir.join("no-artifacts-here").to_string_lossy().to_string();
    run(&[
        "eval-ppl",
        "--artifacts",
        &bogus,
        "--qckpt",
        &out,
        "--exec",
        "native",
        "--corpus-tokens",
        "30000",
        "--eval-batches",
        "2",
    ])
    .unwrap();
    run(&[
        "serve",
        "--artifacts",
        &bogus,
        "--qckpt",
        &out,
        "--exec",
        "native",
        "--prompts",
        "2",
        "--new-tokens",
        "3",
    ])
    .unwrap();
    // assumption honors --exec native too (calibrates on the Rust forward)
    run(&[
        "assumption",
        "--artifacts",
        &bogus,
        "--model",
        "micro",
        "--exec",
        "native",
        "--corpus-tokens",
        "2000",
        "--calib-batches",
        "2",
    ])
    .unwrap();
}

// ----------------------------------------------------- crash recovery

#[test]
fn crash_resume_bit_identity_at_every_shard_boundary() {
    // ISSUE acceptance: crash a streaming run at EVERY shard boundary of an
    // 8-layer model (10 groups at --shard-layers 1, plus the manifest write
    // itself), resume, and land a manifest bit-identical to the uncrashed
    // baseline with `shards_skipped_resume` equal to the shards that had
    // completed before the crash.  Bit-identity holds because per-site
    // solver seeds derive from GLOBAL site indices recorded in the journal.
    use qera::util::fault::{FaultKind, FaultOp, FaultSpec, FaultyIo};
    use std::sync::Arc;

    let dir = tmpdir().join("crash_resume");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let spec = deep_spec(8);
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(61)));
    let src = dir.join("src.qkpt");
    ckpt.save(&src).unwrap();
    let cfg = PipelineConfig::new(Method::WOnly, QFormat::Mxint { bits: 4, block: 32 }, 0);

    // uncrashed baseline; same output file name in every run directory so
    // manifests and shard files compare byte-for-byte with no rewriting
    let base_dir = dir.join("base");
    std::fs::create_dir_all(&base_dir).unwrap();
    let base_out = base_dir.join("q.manifest.json");
    let base_sum = quantize_streaming(&src, &cfg, None, &base_out, 1).unwrap();
    let n_shards = base_sum.n_shards;
    assert_eq!(n_shards, 10, "embed group + 8 layers + tail");
    let base_manifest = std::fs::read(&base_out).unwrap();
    let shard_name = |i: usize| format!("q.shard-{i:03}.bin");
    let base_shards: Vec<Vec<u8>> = (0..n_shards)
        .map(|i| std::fs::read(base_dir.join(shard_name(i))).unwrap())
        .collect();

    // k < n_shards crashes shard k's write; k == n_shards crashes the
    // final manifest write (its tmp file is the only path matching
    // "json.tmp" — journal tmps end in ".journal.tmp")
    for k in 0..=n_shards {
        let run = dir.join(format!("k{k}"));
        std::fs::create_dir_all(&run).unwrap();
        let out = run.join("q.manifest.json");
        let substr = if k < n_shards { format!("shard-{k:03}") } else { "json.tmp".to_string() };
        let crash = StreamOptions {
            io: Some(Arc::new(FaultyIo::std(
                vec![FaultSpec::new(FaultKind::Enospc, FaultOp::Write, substr)],
                7,
            ))),
            ..Default::default()
        };
        let err = quantize_streaming_with(&src, &cfg, None, &out, 1, &crash).unwrap_err();
        assert!(format!("{err:#}").contains("no space"), "k={k}: {err:#}");
        assert!(!out.exists(), "k={k}: a crashed run must not publish a manifest");

        let resume = StreamOptions { resume: true, ..Default::default() };
        let sum = quantize_streaming_with(&src, &cfg, None, &out, 1, &resume).unwrap();
        assert_eq!(sum.shards_skipped_resume, k, "k={k}: journaled shards skipped");
        assert_eq!(std::fs::read(&out).unwrap(), base_manifest, "k={k}: manifest differs");
        for i in 0..n_shards {
            assert_eq!(
                std::fs::read(run.join(shard_name(i))).unwrap(),
                base_shards[i],
                "k={k}: shard {i} differs"
            );
        }
    }
}

#[test]
fn chaos_seeded_single_fault_converges_after_resume() {
    // multi-seed chaos: a seeded RNG scripts one random fault (kind x op x
    // target) into a streaming run with --resume semantics.  Whatever
    // fires, the invariant holds: the run either completes bit-identical
    // to the clean baseline (transient / silently-corrupting faults are
    // ridden out by retry + read-back verification) or fails without
    // publishing a manifest, after which a clean resume converges.
    use qera::util::fault::{FaultKind, FaultOp, FaultSpec, FaultyIo};
    use std::sync::Arc;

    let dir = tmpdir().join("chaos");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let spec = deep_spec(4);
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(71)));
    let src = dir.join("src.qkpt");
    ckpt.save(&src).unwrap();
    let cfg = PipelineConfig::new(Method::WOnly, QFormat::Mxint { bits: 3, block: 32 }, 0);

    let base_dir = dir.join("base");
    std::fs::create_dir_all(&base_dir).unwrap();
    let base_out = base_dir.join("q.manifest.json");
    let base_sum = quantize_streaming(&src, &cfg, None, &base_out, 1).unwrap();
    let n_shards = base_sum.n_shards;
    let base_manifest = std::fs::read(&base_out).unwrap();
    let shard_name = |i: usize| format!("q.shard-{i:03}.bin");
    let base_shards: Vec<Vec<u8>> = (0..n_shards)
        .map(|i| std::fs::read(base_dir.join(shard_name(i))).unwrap())
        .collect();

    let kinds = [
        FaultKind::Torn,
        FaultKind::Flip,
        FaultKind::Enospc,
        FaultKind::Transient,
        FaultKind::Perm,
    ];
    for seed in 0..6u64 {
        let mut rng = Rng::new(0xc4a05 ^ seed);
        let kind = kinds[rng.below(kinds.len())];
        let op = if kind == FaultKind::Enospc || rng.below(2) == 0 {
            FaultOp::Write
        } else {
            FaultOp::Read
        };
        // flip reads only target shard files: the write path's sha256
        // read-back must catch them there, but a monolithic .qkpt source
        // carries no checksum, so a silently flipped source bit is
        // legitimately undetectable
        let substr = if op == FaultOp::Read && kind != FaultKind::Flip && rng.below(2) == 0 {
            "src.qkpt".to_string()
        } else {
            format!("shard-{:03}", rng.below(n_shards))
        };
        let run = dir.join(format!("seed{seed}"));
        std::fs::create_dir_all(&run).unwrap();
        let out = run.join("q.manifest.json");
        let opts = StreamOptions {
            resume: true,
            io: Some(Arc::new(FaultyIo::std(
                vec![FaultSpec::new(kind, op, substr.clone())],
                seed,
            ))),
            ..Default::default()
        };
        let tag = format!("seed {seed}: {}@{op:?}:{substr}", kind.name());
        match quantize_streaming_with(&src, &cfg, None, &out, 1, &opts) {
            Ok(_) => {}
            Err(e) => {
                assert!(!out.exists(), "{tag}: failed run published a manifest ({e:#})");
                let resume = StreamOptions { resume: true, ..Default::default() };
                quantize_streaming_with(&src, &cfg, None, &out, 1, &resume)
                    .unwrap_or_else(|e| panic!("{tag}: clean resume failed: {e:#}"));
            }
        }
        assert_eq!(std::fs::read(&out).unwrap(), base_manifest, "{tag}: manifest differs");
        for i in 0..n_shards {
            assert_eq!(
                std::fs::read(run.join(shard_name(i))).unwrap(),
                base_shards[i],
                "{tag}: shard {i} differs"
            );
        }
    }
}

#[test]
fn serving_consistency_with_direct_eval() {
    // the batcher must produce exactly the greedy tokens the engine produces
    let Some(reg) = registry() else {
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let params = init_params(&spec, &mut Rng::new(9));
    let engine = qera::serve::Engine::new(&reg, spec.clone(), params.clone()).unwrap();
    let prompts = vec![vec![3i32, 1, 4], vec![1i32, 5, 9, 2]];
    let direct = engine.generate(&prompts, 6, 0.0, &mut Rng::new(0)).unwrap();

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let server = qera::serve::Server::start(
        dir,
        spec,
        params,
        qera::serve::ServerConfig {
            max_wait: std::time::Duration::from_millis(1),
            seed: 0,
            ..Default::default()
        },
    );
    for (i, p) in prompts.iter().enumerate() {
        let h = server.submit(p.clone(), 6, 0.0).unwrap();
        let resp = h
            .wait_timeout(std::time::Duration::from_secs(120))
            .expect("terminal outcome")
            .response()
            .unwrap();
        assert_eq!(resp.tokens, direct[i][p.len()..].to_vec(), "prompt {i}");
    }
    server.stop().unwrap();
}

#[test]
fn lora_init_respects_method_semantics() {
    let Some(reg) = registry() else {
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(11)));
    let corpus = Corpus::generate(spec.vocab, 20_000, 12);
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 4, true).unwrap();
    let fmt = QFormat::Mxint { bits: 2, block: 16 };

    // at init, merged(qera) must be closer (in model output) to the full-
    // precision model than merged(qlora) = plain dequantized weights
    let q = qera::train::lora::lora_init(&ckpt, Method::QloraZero, fmt, 8, None, 1).unwrap();
    let e = qera::train::lora::lora_init(&ckpt, Method::QeraApprox, fmt, 8, Some(&calib), 1)
        .unwrap();
    let err_q = qera::eval::model_output_error(
        &reg, &spec, &ckpt.params, &q.merged(&spec), &corpus, 2,
    )
    .unwrap();
    let err_e = qera::eval::model_output_error(
        &reg, &spec, &ckpt.params, &e.merged(&spec), &corpus, 2,
    )
    .unwrap();
    assert!(err_e < err_q, "qera init {err_e} !< qlora init {err_q}");
}

// ---------------------------------------------------------------- daemon

/// Test engine whose `step` signals `started` then blocks until the test
/// feeds a token through `gate` — the deterministic handle the admission /
/// drain tests use to freeze the daemon at a known point.
struct GatedEngine {
    inner: qera::serve::Engine,
    started: std::sync::mpsc::Sender<()>,
    gate: std::sync::Arc<std::sync::Mutex<std::sync::mpsc::Receiver<()>>>,
}

impl qera::serve::BatchEngine for GatedEngine {
    fn spec(&self) -> &ModelSpec {
        &self.inner.spec
    }

    fn backend_name(&self) -> &'static str {
        "gated"
    }

    fn step(
        &self,
        contexts: &[Vec<i32>],
        temperatures: &[f32],
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<i32>> {
        let _ = self.started.send(());
        let _ = self.gate.lock().unwrap().recv();
        self.inner.step_multi(contexts, temperatures, rng)
    }
}

/// A gated-engine server plus the test-side handles: `started` fires once
/// per decode step, `gate` releases one blocked step per token sent.
#[allow(clippy::type_complexity)]
fn gated_server(
    cfg: qera::serve::ServerConfig,
) -> (qera::serve::Server, std::sync::mpsc::Receiver<()>, std::sync::mpsc::Sender<()>) {
    let spec = ModelSpec::builtin("micro").unwrap();
    let params = init_params(&spec, &mut Rng::new(40));
    let (started_tx, started_rx) = std::sync::mpsc::channel();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel();
    let gate = std::sync::Arc::new(std::sync::Mutex::new(gate_rx));
    let server = qera::serve::Server::start_custom(cfg, move || {
        let inner = qera::serve::Engine::new_native(spec.clone(), params.clone())?;
        Ok(Box::new(GatedEngine {
            inner,
            started: started_tx.clone(),
            gate: gate.clone(),
        }) as Box<dyn qera::serve::BatchEngine>)
    });
    (server, started_rx, gate_tx)
}

#[test]
fn daemon_survives_engine_step_fault() {
    // regression for the silent-loss bug: an engine-step error used to kill
    // the serve loop and drop every queued reply channel.  Inject a fault on
    // the first step: the supervisor must rebuild the engine, retry the
    // batch, and complete every request — no client hangs, nothing is lost.
    use std::sync::atomic::{AtomicUsize, Ordering};
    let spec = ModelSpec::builtin("micro").unwrap();
    let params = init_params(&spec, &mut Rng::new(31));
    let builds = std::sync::Arc::new(AtomicUsize::new(0));
    let b = builds.clone();
    let cfg = qera::serve::ServerConfig {
        max_wait: std::time::Duration::from_millis(30),
        retry: qera::serve::RetryPolicy {
            base: std::time::Duration::from_millis(1),
            ..Default::default()
        },
        ..Default::default()
    };
    let server = qera::serve::Server::start_custom(cfg, move || {
        let n = b.fetch_add(1, Ordering::SeqCst);
        let engine = qera::serve::Engine::new_native(spec.clone(), params.clone())?;
        Ok(if n == 0 {
            // first engine dies on its first step; rebuilds are clean
            Box::new(qera::serve::FaultyEngine::new(Box::new(engine), vec![0]))
                as Box<dyn qera::serve::BatchEngine>
        } else {
            Box::new(engine)
        })
    });
    let handles: Vec<_> =
        (0..3i32).map(|i| server.submit(vec![i + 1, 2], 4, 0.0).unwrap()).collect();
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h
            .wait_timeout(std::time::Duration::from_secs(120))
            .expect("no client may hang on an engine fault")
            .response()
            .unwrap_or_else(|e| panic!("request {i} not completed: {e}"));
        assert_eq!(resp.tokens.len(), 4, "request {i}");
    }
    let stats = server.stop().unwrap();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.admitted, 3);
    assert_eq!(stats.accounted(), stats.admitted);
    assert!(stats.retries >= 1, "fault must surface as a retry");
    assert!(stats.engine_restarts >= 1, "supervisor must rebuild the engine");
    assert!(builds.load(Ordering::SeqCst) >= 2);
}

#[test]
fn permanent_engine_outage_degrades_to_typed_failures_and_swap_revives() {
    // every step fails: retries exhaust into Outcome::Failed, the restart
    // budget exhausts into EngineDead shedding + gate rejection — and a hot
    // swap to a working engine resurrects the daemon.
    let spec = ModelSpec::builtin("micro").unwrap();
    let params = init_params(&spec, &mut Rng::new(32));
    let (spec_f, params_f) = (spec.clone(), params.clone());
    let cfg = qera::serve::ServerConfig {
        max_wait: std::time::Duration::from_millis(5),
        retry: qera::serve::RetryPolicy {
            max_retries: 1,
            base: std::time::Duration::from_millis(1),
            ..Default::default()
        },
        max_restarts: 1,
        ..Default::default()
    };
    let server = qera::serve::Server::start_custom(cfg, move || {
        let engine = qera::serve::Engine::new_native(spec_f.clone(), params_f.clone())?;
        Ok(Box::new(qera::serve::FaultyEngine::always_failing(Box::new(engine)))
            as Box<dyn qera::serve::BatchEngine>)
    });
    // first request: typed failure after 1 + max_retries attempts
    let h1 = server.submit(vec![1, 2], 3, 0.0).unwrap();
    match h1.wait_timeout(std::time::Duration::from_secs(120)).expect("terminal outcome") {
        qera::serve::Outcome::Failed { error, attempts } => {
            assert_eq!(attempts, 2);
            assert!(error.contains("injected engine fault"), "{error}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    // second request: the restart budget is spent -> shed as EngineDead
    let h2 = server.submit(vec![3, 4], 3, 0.0).unwrap();
    match h2.wait_timeout(std::time::Duration::from_secs(120)).expect("terminal outcome") {
        qera::serve::Outcome::Shed(qera::serve::ShedReason::EngineDead) => {}
        other => panic!("expected Shed(EngineDead), got {other:?}"),
    }
    // gate now rejects synchronously: the dead daemon is observable
    match server.submit(vec![5, 6], 3, 0.0) {
        Err(qera::serve::SubmitError::Rejected(qera::serve::ShedReason::EngineDead)) => {}
        other => panic!("expected EngineDead rejection, got {other:?}"),
    }
    // hot swap to a working engine revives serving
    let (spec_g, params_g) = (spec.clone(), params.clone());
    server
        .swap_factory(
            move || {
                Ok(Box::new(qera::serve::Engine::new_native(
                    spec_g.clone(),
                    params_g.clone(),
                )?) as Box<dyn qera::serve::BatchEngine>)
            },
            qera::serve::PlanTelemetry::default(),
        )
        .unwrap();
    let h4 = server.submit(vec![7, 8], 3, 0.0).unwrap();
    let resp = h4
        .wait_timeout(std::time::Duration::from_secs(120))
        .expect("terminal outcome")
        .response()
        .unwrap();
    assert_eq!(resp.tokens.len(), 3);
    assert_eq!(resp.model_version, 1);

    let stats = server.stop().unwrap();
    assert_eq!(stats.admitted, 3); // h1, h2, h4 (h3 was gate-rejected)
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.errored, 1);
    assert_eq!(stats.shed, 1);
    assert_eq!(stats.rejected_at_gate, 1);
    assert_eq!(stats.swaps, 1);
    assert!(stats.engine_restarts >= 1);
    assert_eq!(stats.accounted(), stats.admitted, "every admitted request accounted");
}

#[test]
fn hot_swap_to_budget_plan_under_load() {
    // ISSUE acceptance: swap a BudgetPlan checkpoint in under concurrent
    // load — zero dropped in-flight requests, and post-swap ServerStats
    // surface the new plan's telemetry.
    let spec = ModelSpec::builtin("micro").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(33)));

    // model A: plain w-only quant (no plan telemetry)
    let qa = quantize(
        &ckpt,
        &PipelineConfig::new(Method::WOnly, QFormat::Mxint { bits: 4, block: 32 }, 0),
        None,
    )
    .unwrap();
    // model B: greedy BudgetPlan execution (carries plan_bits/plan_strategy)
    let calib = CalibResult::synthetic(&spec, 64, 34);
    let base = PipelineConfig::new(Method::QeraApprox, QFormat::Mxint { bits: 4, block: 32 }, 4);
    let prof = profile(&ckpt, &calib, &base, &CandidateGrid::default_ptq()).unwrap();
    let plan = allocate(&prof, 4.0, AllocStrategy::Greedy).unwrap();
    let planned_bits = plan.achieved_bits;
    let qb = quantize(&ckpt, &base.with_plan(plan), Some(&calib)).unwrap();
    let (meta_bits, meta_strategy) = qb.ckpt.plan_telemetry();
    assert_eq!(meta_strategy.as_deref(), Some("greedy"));
    assert!(meta_bits.is_some());

    let server = qera::serve::Server::start_model(
        PathBuf::from("/nonexistent-artifacts"),
        spec.clone(),
        qera::serve::ServeModel::Quant(Box::new(qa.ckpt)),
        qera::serve::ServerConfig {
            max_wait: std::time::Duration::from_millis(20),
            backend: qera::runtime::ExecBackend::Native,
            ..Default::default()
        },
    );
    let wait = |h: Result<qera::serve::RequestHandle, qera::serve::SubmitError>| {
        h.unwrap()
            .wait_timeout(std::time::Duration::from_secs(120))
            .expect("terminal outcome")
            .response()
            .expect("completed")
    };
    // wave 1 on the old model
    let w1: Vec<_> = (0..2i32).map(|i| server.submit(vec![i + 1, 2], 3, 0.0)).collect();
    for h in w1 {
        assert_eq!(wait(h).model_version, 0);
    }
    // wave 2 in flight while the swap lands: whichever engine serves it,
    // every request completes — zero dropped
    let w2: Vec<_> = (0..2i32).map(|i| server.submit(vec![i + 3, 1], 3, 0.0)).collect();
    server
        .swap_model(spec.clone(), qera::serve::ServeModel::Quant(Box::new(qb.ckpt)))
        .unwrap();
    for h in w2 {
        assert_eq!(wait(h).tokens.len(), 3);
    }
    // wave 3 decodes on the new model
    let w3: Vec<_> = (0..2i32).map(|i| server.submit(vec![i + 5, 3], 3, 0.0)).collect();
    for h in w3 {
        assert_eq!(wait(h).model_version, 1);
    }

    let stats = server.stop().unwrap();
    assert_eq!(stats.requests, 6, "zero dropped requests across the swap");
    assert_eq!(stats.shed + stats.timed_out + stats.cancelled + stats.errored, 0);
    assert_eq!(stats.swaps, 1);
    assert_eq!(stats.plan_strategy.as_deref(), Some("greedy"));
    let bits = stats.plan_bits.expect("plan bits surfaced in telemetry");
    assert!((bits - planned_bits).abs() < 1e-9);
    assert_eq!(stats.accounted(), stats.admitted);
}

#[test]
fn bounded_queue_rejects_deterministically() {
    // seed-free determinism by construction: the gate counts waiting
    // requests, and the gated engine freezes the daemon mid-batch so the
    // queue depth at each submit is exact, not racy.
    let (server, started, gate) = gated_server(qera::serve::ServerConfig {
        max_wait: std::time::Duration::from_millis(0),
        queue_cap: 2,
        inflight_cap: 1,
        ..Default::default()
    });
    // A is popped into a batch (leaves the queue), then blocks in step
    let ha = server.submit(vec![1, 2], 1, 0.0).unwrap();
    started.recv().unwrap();
    // B and C fill the queue to its cap
    let hb = server.submit(vec![3, 4], 1, 0.0).unwrap();
    let hc = server.submit(vec![5, 6], 1, 0.0).unwrap();
    // D must be rejected synchronously
    match server.submit(vec![7, 8], 1, 0.0) {
        Err(qera::serve::SubmitError::Rejected(qera::serve::ShedReason::QueueFull)) => {}
        other => panic!("expected QueueFull rejection, got {other:?}"),
    }
    // release one step per request; all three admitted requests complete
    for _ in 0..3 {
        gate.send(()).unwrap();
    }
    for h in [ha, hb, hc] {
        h.wait_timeout(std::time::Duration::from_secs(120))
            .expect("terminal outcome")
            .response()
            .unwrap();
    }
    let stats = server.stop().unwrap();
    assert_eq!(stats.admitted, 3);
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.rejected_at_gate, 1);
    assert_eq!(stats.accounted(), stats.admitted);
}

#[test]
fn drain_sheds_queued_work_past_the_deadline() {
    // shutdown ordering, zero drain budget: the in-flight batch completes,
    // everything still queued when the drain deadline passes is shed with a
    // typed Draining outcome, and the stats account for every admitted
    // request — nothing is silently dropped.
    let (mut server, started, gate) = gated_server(qera::serve::ServerConfig {
        max_wait: std::time::Duration::from_millis(0),
        drain: std::time::Duration::from_millis(0),
        ..Default::default()
    });
    let ha = server.submit(vec![1, 2], 1, 0.0).unwrap();
    started.recv().unwrap(); // A is mid-batch, daemon frozen on the gate
    server.begin_stop(); // Stop is now queued ahead of anything later
    // B and C still pass the admission gate (the draining flag is only set
    // once the daemon reaches the Stop message) and land in the channel
    // behind it — the drain's backlog sweep is what must account for them
    let hb = server.submit(vec![3, 4], 1, 0.0).unwrap();
    let hc = server.submit(vec![5, 6], 1, 0.0).unwrap();
    gate.send(()).unwrap(); // release A
    let a = ha
        .wait_timeout(std::time::Duration::from_secs(120))
        .expect("terminal outcome")
        .response()
        .unwrap();
    assert_eq!(a.tokens.len(), 1, "in-flight work survives the drain");
    for (name, h) in [("B", hb), ("C", hc)] {
        match h.wait_timeout(std::time::Duration::from_secs(120)).expect("terminal outcome") {
            qera::serve::Outcome::Shed(qera::serve::ShedReason::Draining) => {}
            other => panic!("expected {name} shed as Draining, got {other:?}"),
        }
    }
    let stats = server.stop().unwrap();
    assert_eq!(stats.admitted, 3);
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.shed, 2);
    assert_eq!(stats.accounted(), stats.admitted, "counts sum to submissions");
}

#[test]
fn drain_completes_backlog_and_rejects_late_submissions() {
    // shutdown ordering with a generous drain budget: work queued ahead of
    // the stop is finished, and once the daemon is draining, new
    // submissions are rejected synchronously at the gate.
    let (mut server, started, gate) = gated_server(qera::serve::ServerConfig {
        max_wait: std::time::Duration::from_millis(0),
        inflight_cap: 1,
        drain: std::time::Duration::from_secs(30),
        ..Default::default()
    });
    let ha = server.submit(vec![1, 2], 1, 0.0).unwrap();
    started.recv().unwrap(); // A mid-batch, daemon frozen
    let hb = server.submit(vec![3, 4], 1, 0.0).unwrap(); // queued ahead of stop
    server.begin_stop();
    gate.send(()).unwrap(); // release A
    started.recv().unwrap(); // B's batch began (inflight_cap=1 keeps it solo)
    gate.send(()).unwrap(); // release B
    for (name, h) in [("A", ha), ("B", hb)] {
        let resp = h
            .wait_timeout(std::time::Duration::from_secs(120))
            .expect("terminal outcome")
            .response()
            .unwrap_or_else(|e| panic!("{name} must complete before shutdown: {e}"));
        assert_eq!(resp.tokens.len(), 1, "{name}");
    }
    // the daemon now reaches the Stop message and flips the draining flag;
    // from that point submissions are rejected at the gate
    while !server.is_draining() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    match server.submit(vec![5, 6], 1, 0.0) {
        Err(qera::serve::SubmitError::Rejected(qera::serve::ShedReason::Draining)) => {}
        other => panic!("expected Draining rejection, got {other:?}"),
    }
    let stats = server.stop().unwrap();
    assert_eq!(stats.admitted, 2);
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.rejected_at_gate, 1);
    assert_eq!(stats.accounted(), stats.admitted, "counts sum to submissions");
}

#[test]
fn stub_backend_shutdown_accounting() {
    // satellite: shutdown accounting must hold on the artifact/stub backend
    // too, not just native
    let Some(reg) = registry() else {
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let params = init_params(&spec, &mut Rng::new(41));
    let server = qera::serve::Server::start(
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        spec,
        params,
        qera::serve::ServerConfig {
            max_wait: std::time::Duration::from_millis(10),
            ..Default::default()
        },
    );
    let handles: Vec<_> =
        (0..4i32).map(|i| server.submit(vec![i + 1, 3], 4, 0.0).unwrap()).collect();
    for h in handles {
        h.wait_timeout(std::time::Duration::from_secs(120))
            .expect("terminal outcome")
            .response()
            .unwrap();
    }
    let stats = server.stop().unwrap();
    assert_eq!(stats.admitted, 4);
    assert_eq!(stats.requests, 4);
    assert_eq!(stats.accounted(), stats.admitted);
    assert_eq!(stats.rejected_at_gate, 0);
}

#[test]
fn manifest_covers_every_needed_artifact() {
    let Some(reg) = registry() else {
        return;
    };
    let arts = [
        "lm_fwd",
        "lm_nll",
        "lm_logits_last",
        "lm_fwd_taps",
        "lm_pool",
        "pretrain_step",
        "full_cls_step",
    ];
    for cfg in ["nano", "small"] {
        for art in arts {
            assert!(reg.info(&format!("{art}.{cfg}")).is_ok(), "{art}.{cfg}");
        }
    }
    assert!(reg.info("lora_cls_step.small.r12").is_ok());
    assert!(reg.info("qlinear.m64k128n96r8").is_ok());
}
