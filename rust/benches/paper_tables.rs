//! Regenerates every paper *table* (DESIGN.md §5 maps table -> function).
//!
//! ```bash
//! cargo bench --bench paper_tables              # all tables, quick scale
//! cargo bench --bench paper_tables -- table3    # one table
//! QERA_BENCH_SCALE=full cargo bench --bench paper_tables
//! ```

use qera::experiments::{ptq, qpeft, Scale};
use qera::runtime::Registry;

fn main() -> anyhow::Result<()> {
    // cargo bench passes harness flags like `--bench`; keep only filters
    let args: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a.contains(name));
    let scale = Scale::from_env();
    let reg = Registry::open_default()?;
    // experiment model: small at full scale, nano for the quick loop
    let model = match scale {
        Scale::Quick => "nano",
        Scale::Full => "small",
    };
    println!("== paper tables ({scale:?}, model {model}) ==");

    if want("table1") {
        qpeft::table1(&reg, model, scale)?.emit("table1");
    }
    if want("table2") {
        qpeft::table2(&reg, model, scale)?.emit("table2");
    }
    if want("table3") {
        let models: Vec<&str> =
            if scale == Scale::Full { vec!["nano", "small"] } else { vec!["nano"] };
        ptq::table3(&reg, &models, scale)?.emit("table3");
    }
    if want("table4") {
        ptq::table4(&reg, model, scale)?.emit("table4");
    }
    if want("table7") || want("table8") {
        qpeft::table7(&reg, model, scale)?.emit("table7_8");
    }
    if want("table9") || want("table10") {
        // the rank sweep needs the cls-rank artifact set {4..20} (small)
        let sweep_model = if reg.specs.contains_key("small") { "small" } else { model };
        qpeft::table9(&reg, sweep_model, scale)?.emit("table9_10");
    }
    if want("budget") {
        // beyond the paper: per-layer budget plans at matched bits/weight
        qera::experiments::budget::budget_sweep(&reg, model, scale)?.emit("budget_sweep");
    }
    Ok(())
}
