//! Regenerates every paper *figure* as a data series (DESIGN.md §5).
//!
//! ```bash
//! cargo bench --bench paper_figures             # all figures, quick scale
//! cargo bench --bench paper_figures -- fig3     # one figure
//! ```

use qera::experiments::{analysis, ptq, qpeft, Scale};
use qera::runtime::Registry;

fn main() -> anyhow::Result<()> {
    // cargo bench passes harness flags like `--bench`; keep only filters
    let args: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a.contains(name));
    let scale = Scale::from_env();
    let reg = Registry::open_default()?;
    let model = match scale {
        Scale::Quick => "nano",
        Scale::Full => "small",
    };
    println!("== paper figures ({scale:?}, model {model}) ==");

    if want("fig1") {
        let (a, b) = qpeft::fig1(&reg, model, scale)?;
        a.emit("fig1a");
        b.emit("fig1b");
    }
    if want("fig2") {
        qpeft::fig2(&reg, model, scale)?.emit("fig2");
    }
    if want("fig3") {
        ptq::fig3(&reg, model, scale)?.emit("fig3");
    }
    if want("fig4") {
        ptq::fig4(&reg, model, scale)?.emit("fig4");
    }
    if want("fig5") {
        analysis::fig5(&reg, model, scale)?.emit("fig5");
    }
    if want("fig6") {
        analysis::fig6(&reg, model, scale)?.emit("fig6");
    }
    if want("fig7") {
        qpeft::fig7(&reg, model, scale)?.emit("fig7");
    }
    if want("fig8") {
        analysis::fig8a(scale)?.emit("fig8a");
        analysis::fig8b(&reg, model, scale)?.emit("fig8b");
    }
    Ok(())
}
