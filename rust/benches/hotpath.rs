//! Hot-path microbenchmarks (the §Perf layer-by-layer numbers).
//!
//! ```bash
//! cargo bench --bench hotpath                  # everything
//! cargo bench --bench hotpath -- eigh          # one group
//! ```
//!
//! Groups: `eigh` (L3 solver core), `solver` (per-layer solve), `forward`
//! (PJRT lm_fwd / qlinear), `serve` (batcher throughput), `quant`
//! (quantizer kernels), `stats` (calibration accumulation).

use qera::bench_util::{f2, f3, time_stats, Table};
use qera::linalg::{eigh_jacobi, eigh::eigh_tridiag, svd_thin, Mat64};
use qera::quant::QFormat;
use qera::runtime::{exec::lm_inputs, Registry};
use qera::solver::Method;
use qera::stats::CalibStats;
use qera::tensor::Tensor;
use qera::util::rng::Rng;

fn rand_psd(n: usize, seed: u64) -> Mat64 {
    let mut rng = Rng::new(seed);
    let m = Mat64::from_vec(n, 2 * n, (0..2 * n * n).map(|_| rng.normal()).collect());
    m.matmul_nt(&m).scale(1.0 / (2 * n) as f64)
}

fn bench_eigh() {
    let mut t = Table::new(
        "eigh: tridiagonal-QL fast path vs cyclic Jacobi (ms)",
        &["dim", "tridiag p50", "jacobi p50", "speedup"],
    );
    for n in [64usize, 128, 256] {
        let a = rand_psd(n, n as u64);
        let iters = if n >= 256 { 3 } else { 10 };
        let tr = time_stats(1, iters, || {
            std::hint::black_box(eigh_tridiag(&a));
        });
        let ja = time_stats(1, iters.min(3), || {
            std::hint::black_box(eigh_jacobi(&a));
        });
        t.row(vec![
            n.to_string(),
            f2(tr.p50_ms),
            f2(ja.p50_ms),
            f2(ja.p50_ms / tr.p50_ms),
        ]);
    }
    t.emit("hot_eigh");
}

fn bench_svd() {
    let mut t = Table::new("svd_thin (ms)", &["shape", "p50", "p95"]);
    let mut rng = Rng::new(0);
    for (m, n) in [(64usize, 256usize), (128, 512), (256, 256)] {
        let a = Mat64::from_vec(m, n, (0..m * n).map(|_| rng.normal()).collect());
        let s = time_stats(1, 5, || {
            std::hint::black_box(svd_thin(&a));
        });
        t.row(vec![format!("{m}x{n}"), f2(s.p50_ms), f2(s.p95_ms)]);
    }
    t.emit("hot_svd");
}

fn bench_solver(reg: &Registry) -> anyhow::Result<()> {
    let spec = reg.spec("nano")?.clone();
    let mut rng = Rng::new(1);
    let params = qera::model::init::init_params(&spec, &mut rng);
    let ckpt = qera::model::Checkpoint::new(spec.clone(), params);
    let corpus = qera::data::Corpus::generate(spec.vocab, 60_000, 2);
    let calib = qera::coordinator::calibrate(reg, &spec, &ckpt.params, &corpus, 8, true)?;
    let fmt = QFormat::Mxint { bits: 3, block: 32 };
    let mut t = Table::new(
        "per-model solve wall time (12 layers, nano)",
        &["method", "total ms p50"],
    );
    for method in [Method::ZeroQuantV2, Method::Lqer, Method::QeraApprox, Method::QeraExact] {
        let s = time_stats(1, 3, || {
            let cfg = qera::coordinator::PipelineConfig::new(method, fmt, 8);
            std::hint::black_box(qera::coordinator::quantize(&ckpt, &cfg, Some(&calib)).unwrap());
        });
        t.row(vec![method.name(), f2(s.p50_ms)]);
    }
    t.emit("hot_solver");
    Ok(())
}

fn bench_forward(reg: &Registry) -> anyhow::Result<()> {
    let spec = reg.spec("nano")?.clone();
    let mut rng = Rng::new(3);
    let params = qera::model::init::init_params(&spec, &mut rng);
    let tokens: Vec<i32> =
        (0..spec.batch * spec.seq).map(|_| rng.below(spec.vocab) as i32).collect();
    let shape = [spec.batch, spec.seq];
    let mut t = Table::new(
        "PJRT forward latency (nano)",
        &["artifact", "p50 ms", "p95 ms", "tok/s"],
    );
    for name in ["lm_fwd.nano", "lm_nll.nano", "lm_logits_last.nano", "lm_fwd_taps.nano"] {
        let exec = reg.load(name)?;
        let needs_targets = exec.info.inputs.iter().any(|i| i.name == "targets");
        let s = time_stats(2, 20, || {
            let inputs = if needs_targets {
                lm_inputs(&tokens, Some((&tokens, &shape)), &shape, &params)
            } else {
                lm_inputs(&tokens, None, &shape, &params)
            };
            std::hint::black_box(exec.run(&inputs).unwrap());
        });
        let toks = (spec.batch * spec.seq) as f64 / (s.p50_ms / 1e3);
        t.row(vec![name.to_string(), f2(s.p50_ms), f2(s.p95_ms), format!("{toks:.0}")]);
    }

    // fused low-rank serving form vs dense (the no-overhead claim)
    let exec_lr = reg.load(&format!("lm_fwd_lr.nano.r8"))?;
    let lora: Vec<Tensor> = spec
        .lora_layout(8)
        .into_iter()
        .map(|(_, shape)| Tensor::randn(shape, 0.01, &mut rng))
        .collect();
    let s = time_stats(2, 20, || {
        let mut inputs = lm_inputs(&tokens, None, &shape, &params);
        inputs.extend(lora.iter().cloned().map(qera::runtime::Value::F32));
        std::hint::black_box(exec_lr.run(&inputs).unwrap());
    });
    let toks = (spec.batch * spec.seq) as f64 / (s.p50_ms / 1e3);
    t.row(vec!["lm_fwd_lr.nano.r8 (A,B separate)".into(), f2(s.p50_ms), f2(s.p95_ms), format!("{toks:.0}")]);
    t.emit("hot_forward");
    Ok(())
}

fn bench_quant() {
    let mut rng = Rng::new(4);
    let w = Tensor::randn(vec![512, 512], 0.02, &mut rng);
    let mut t = Table::new("quantizer throughput (512x512 weight)", &["format", "p50 ms", "Melem/s"]);
    for fmt in [
        QFormat::Mxint { bits: 4, block: 32 },
        QFormat::Mxint { bits: 2, block: 16 },
        QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
        QFormat::Fp4 { group: 64 },
    ] {
        let s = time_stats(1, 10, || {
            std::hint::black_box(fmt.qdq(&w));
        });
        t.row(vec![fmt.name(), f3(s.p50_ms), format!("{:.1}", 512.0 * 512.0 / 1e6 / (s.p50_ms / 1e3))]);
    }
    t.emit("hot_quant");
}

fn bench_stats() {
    let mut rng = Rng::new(5);
    let x = Tensor::randn(vec![256, 256], 1.0, &mut rng);
    let mut t = Table::new(
        "calibration accumulation (256 rows x 256 dims)",
        &["mode", "p50 ms"],
    );
    let s1 = time_stats(1, 10, || {
        let mut st = CalibStats::new(256, true);
        st.update(&x);
        std::hint::black_box(st);
    });
    let s2 = time_stats(1, 10, || {
        let mut st = CalibStats::new(256, false);
        st.update(&x);
        std::hint::black_box(st);
    });
    t.row(vec!["with R_XX".into(), f2(s1.p50_ms)]);
    t.row(vec!["diag only".into(), f2(s2.p50_ms)]);
    t.emit("hot_stats");
}

fn bench_serve(reg: &Registry) -> anyhow::Result<()> {
    use std::time::Duration;
    let spec = reg.spec("nano")?.clone();
    let mut rng = Rng::new(6);
    let params = qera::model::init::init_params(&spec, &mut rng);
    let mut t = Table::new(
        "serving throughput vs batching window",
        &["max-wait ms", "requests", "tok/s", "mean batch"],
    );
    for wait_ms in [0u64, 10, 50] {
        let server = qera::serve::Server::start(
            reg.dir.clone(),
            spec.clone(),
            params.clone(),
            qera::serve::ServerConfig { max_wait: Duration::from_millis(wait_ms), seed: 1 },
        );
        let rxs: Vec<_> = (0..8).map(|i| server.submit(vec![i as i32 + 1, 2], 8, 0.0)).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(300))?;
        }
        let stats = server.stop();
        t.row(vec![
            wait_ms.to_string(),
            stats.requests.to_string(),
            format!("{:.1}", stats.throughput_tok_s()),
            f2(stats.mean_batch()),
        ]);
    }
    t.emit("hot_serve");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // cargo bench passes harness flags like `--bench`; keep only filters
    let args: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a.contains(name));
    println!("== hotpath microbenchmarks ==");
    if want("eigh") {
        bench_eigh();
    }
    if want("svd") {
        bench_svd();
    }
    if want("quant") {
        bench_quant();
    }
    if want("stats") {
        bench_stats();
    }
    let reg = Registry::open_default()?;
    if want("solver") {
        bench_solver(&reg)?;
    }
    if want("forward") {
        bench_forward(&reg)?;
    }
    if want("serve") {
        bench_serve(&reg)?;
    }
    Ok(())
}
