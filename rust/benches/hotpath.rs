//! Hot-path microbenchmarks (the §Perf layer-by-layer numbers).
//!
//! ```bash
//! cargo bench --bench hotpath                  # everything
//! cargo bench --bench hotpath -- svd           # one group
//! ```
//!
//! Groups: `eigh` (L3 solver core), `svd` (exact vs randomized truncation),
//! `matmul` (blocked/threaded `Mat64` kernels), `tensor_matmul` (naive vs
//! blocked/threaded f32 `Tensor` kernels — low-rank merges / checkpoint
//! materialization), `psd` (exact vs low-rank `(R½, R^{-½})` pair),
//! `solver` (per-layer solve, exact vs randomized backend), `calib` (the
//! calibration `R_XX` fold: seed scalar loop vs blocked/threaded SYRK),
//! `qdq` (quantizer kernels, serial vs pool-threaded block chunks),
//! `budget` (the mixed-precision planner: layer × cell profiling +
//! allocator sweeps), `exec` (fused-from-packed matmul vs
//! dequantize-then-matmul — the native serve/eval hot path), `serve` (the
//! supervised daemon end to end on the native backend: throughput + queue /
//! total latency tails vs batching window), `ckpt` (checkpoint I/O:
//! sharded-manifest write and sha256-verified parallel reload vs the
//! monolithic path), `obs` (observability per-site overhead: spans with
//! tracing off/on and cached metric handles — the no-op fast-path gate),
//! `quant` (quantizer throughput), `stats` (calibration accumulation), and
//! — when PJRT artifacts are built — `forward`.
//!
//! The `svd` / `matmul` / `tensor_matmul` / `psd` / `solver` / `calib` /
//! `qdq` / `budget` / `exec` / `serve` / `ckpt` / `obs` groups additionally
//! land in `BENCH_solver.json` (machine-readable, for the perf trajectory
//! and the CI bench-regression gate; `serve` is gated on its p95 tail
//! columns too — the SLO gate).  Set `QERA_BENCH_SMOKE=1` to shrink
//! shapes/iterations — the mode CI uses when diffing against
//! `BENCH_baseline.json`.

use qera::bench_util::{emit_json_report, f2, f3, f4, time_stats, Table};
use qera::coordinator::{quantize, CalibResult, PipelineConfig};
use qera::linalg::{
    eigh_jacobi, eigh::eigh_tridiag, psd_sqrt_pair, psd_sqrt_pair_lowrank, svd_randomized,
    svd_thin, Mat64,
};
use qera::model::ModelSpec;
use qera::quant::QFormat;
use qera::runtime::{exec::lm_inputs, Registry};
use qera::solver::{Method, SvdBackend};
use qera::stats::CalibStats;
use qera::tensor::Tensor;
use qera::util::rng::Rng;

/// Smoke mode: smaller shapes / fewer iterations (CI's bench-gate profile).
fn smoke() -> bool {
    std::env::var("QERA_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

fn rand_psd(n: usize, seed: u64) -> Mat64 {
    let mut rng = Rng::new(seed);
    let m = Mat64::from_vec(n, 2 * n, (0..2 * n * n).map(|_| rng.normal()).collect());
    m.matmul_nt(&m).scale(1.0 / (2 * n) as f64)
}

/// Spiked-spectrum PSD (the shape of a calibration `R_XX`): a decaying head
/// on top of a flat tail.
fn spiked_psd(n: usize, seed: u64) -> Mat64 {
    let mut rng = Rng::new(seed);
    let mut q = Mat64::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
    q.orthonormalize_cols();
    let mut qd = q.clone();
    for j in 0..n {
        let d = if j < 16 { 40.0 * 0.7f64.powi(j as i32) } else { 0.3 };
        for i in 0..n {
            qd.a[i * n + j] *= d;
        }
    }
    qd.matmul_nt(&q)
}

fn bench_eigh() {
    let mut t = Table::new(
        "eigh: tridiagonal-QL fast path vs cyclic Jacobi (ms)",
        &["dim", "tridiag p50", "jacobi p50", "speedup"],
    );
    for n in [64usize, 128, 256] {
        let a = rand_psd(n, n as u64);
        let iters = if n >= 256 { 3 } else { 10 };
        let tr = time_stats(1, iters, || {
            std::hint::black_box(eigh_tridiag(&a));
        });
        let ja = time_stats(1, iters.min(3), || {
            std::hint::black_box(eigh_jacobi(&a));
        });
        t.row(vec![
            n.to_string(),
            f2(tr.p50_ms),
            f2(ja.p50_ms),
            f2(ja.p50_ms / tr.p50_ms),
        ]);
    }
    t.emit("hot_eigh");
}

/// Exact thin SVD vs the Halko randomized rank-k path (the solver fast
/// path).  The 256×1024 rank-32 row is the tentpole target: randomized
/// should be >= 4x faster than `svd_thin`.
fn bench_svd() -> Table {
    let mut t = Table::new(
        "svd: thin (exact) vs randomized rank-k (ms)",
        &["shape", "rank", "thin p50", "rand p50", "speedup"],
    );
    let mut rng = Rng::new(0);
    let shapes: &[(usize, usize, usize)] = if smoke() {
        &[(64usize, 256usize, 8usize), (128, 512, 16)]
    } else {
        &[(64usize, 256usize, 8usize), (128, 512, 16), (256, 1024, 32)]
    };
    for &(m, n, k) in shapes {
        let a = Mat64::from_vec(m, n, (0..m * n).map(|_| rng.normal()).collect());
        let iters = if m >= 256 { 3 } else { 5 };
        let thin = time_stats(1, iters, || {
            std::hint::black_box(svd_thin(&a));
        });
        let rand = time_stats(1, iters * 3, || {
            std::hint::black_box(svd_randomized(&a, k, 8, 2));
        });
        t.row(vec![
            format!("{m}x{n}"),
            k.to_string(),
            f4(thin.p50_ms),
            f4(rand.p50_ms),
            f2(thin.p50_ms / rand.p50_ms),
        ]);
    }
    t.emit("hot_svd");
    t
}

/// Exact O(m³) `(R½, R^{-½})` pair vs the low-rank + diagonal split on a
/// spiked-spectrum `R_XX` (the qera-exact whitening hot path).  `k` is the
/// subspace size `rank_mult · rank` at the rank the solver reconstructs.
fn bench_psd() -> Table {
    let mut t = Table::new(
        "psd: exact sqrt pair vs low-rank + diagonal split (ms)",
        &["dim", "k", "exact p50", "lowrank p50", "speedup"],
    );
    // k must satisfy 2k < m or psd_sqrt_pair_lowrank falls back to exact.
    // (64, 16) is nano's d_model at rank 8 · rank_mult 2 — there the inner
    // eigh_topk still takes its dense path (k·4 >= m), so the row measures
    // the split's O(m²k) assembly against the exact recompose (≈1x, the
    // honest nano cost); the subspace win shows at (256, 32) = nano's d_ff
    // at rank 8 · rank_mult 4, and at (512, 64).
    let shapes: &[(usize, usize)] =
        if smoke() { &[(64, 16), (256, 32)] } else { &[(64, 16), (256, 32), (512, 64)] };
    for &(m, k) in shapes {
        let r = spiked_psd(m, m as u64);
        let iters = if smoke() {
            2
        } else if m >= 512 {
            3
        } else {
            5
        };
        let exact = time_stats(1, iters, || {
            std::hint::black_box(psd_sqrt_pair(&r, qera::linalg::psd::EIG_CLAMP_REL));
        });
        let low = time_stats(1, iters, || {
            std::hint::black_box(psd_sqrt_pair_lowrank(
                &r,
                qera::linalg::psd::EIG_CLAMP_REL,
                k,
                32,
            ));
        });
        t.row(vec![
            m.to_string(),
            k.to_string(),
            f4(exact.p50_ms),
            f4(low.p50_ms),
            f2(exact.p50_ms / low.p50_ms),
        ]);
    }
    t.emit("hot_psd");
    t
}

/// f32 `Tensor` kernels: the naive triple loop the blocked kernels replaced
/// vs serial-blocked vs auto-threaded (the low-rank merge / checkpoint
/// materialization path; PJRT does the forward/serve matmuls on device).
fn bench_tensor_matmul() -> Table {
    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        let (ad, bd) = (a.data(), b.data());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = ad[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += av * bd[kk * n + j];
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }
    let mut t = Table::new(
        "tensor_matmul: f32 kernels, naive vs blocked serial vs auto (ms)",
        &["shape", "naive p50", "serial p50", "auto p50", "speedup vs naive"],
    );
    let mut rng = Rng::new(2);
    let shapes: &[(usize, usize, usize)] = if smoke() {
        &[(256, 256, 256)]
    } else {
        // 64-wide rows are the nano layer shapes; the larger shapes are
        // merged-weight materialization at small/medium model widths
        &[(64usize, 64usize, 64usize), (256, 256, 256), (256, 1024, 256), (512, 512, 512)]
    };
    for &(m, k, n) in shapes {
        let a = Tensor::randn(vec![m, k], 1.0, &mut rng);
        let b = Tensor::randn(vec![k, n], 1.0, &mut rng);
        let iters = if smoke() { 2 } else { 5 };
        let nv = time_stats(1, iters, || {
            std::hint::black_box(naive(&a, &b));
        });
        let serial = time_stats(1, iters, || {
            std::hint::black_box(a.matmul_workers(&b, 1));
        });
        let auto = time_stats(1, iters, || {
            std::hint::black_box(a.matmul(&b));
        });
        t.row(vec![
            format!("{m}x{k}x{n}"),
            f4(nv.p50_ms),
            f4(serial.p50_ms),
            f4(auto.p50_ms),
            f2(nv.p50_ms / auto.p50_ms),
        ]);
    }
    t.emit("hot_tensor_matmul");
    t
}

/// Blocked matmul kernels: single worker vs auto-threaded.
fn bench_matmul() -> Table {
    let mut t = Table::new(
        "matmul: blocked kernels, 1 worker vs auto (ms)",
        &["shape", "serial p50", "auto p50", "speedup", "GFLOP/s (auto)"],
    );
    let mut rng = Rng::new(1);
    let shapes: &[(usize, usize, usize)] = if smoke() {
        &[(256usize, 256usize, 256usize)]
    } else {
        &[(256usize, 256usize, 256usize), (256, 1024, 256), (512, 512, 512)]
    };
    for &(m, k, n) in shapes {
        let a = Mat64::from_vec(m, k, (0..m * k).map(|_| rng.normal()).collect());
        let b = Mat64::from_vec(k, n, (0..k * n).map(|_| rng.normal()).collect());
        let serial = time_stats(1, 5, || {
            std::hint::black_box(a.matmul_workers(&b, 1));
        });
        let auto = time_stats(1, 5, || {
            std::hint::black_box(a.matmul(&b));
        });
        let gflops = 2.0 * (m * k * n) as f64 / 1e9 / (auto.p50_ms / 1e3);
        t.row(vec![
            format!("{m}x{k}x{n}"),
            f4(serial.p50_ms),
            f4(auto.p50_ms),
            f2(serial.p50_ms / auto.p50_ms),
            f2(gflops),
        ]);
    }
    t.emit("hot_matmul");
    t
}

/// Per-model solve wall time on nano, exact vs randomized SVD backend.
/// Uses synthetic calibration statistics, so it runs without artifacts.
fn bench_solver() -> Table {
    let spec = ModelSpec::builtin("nano").expect("builtin nano spec");
    let mut rng = Rng::new(1);
    let params = qera::model::init::init_params(&spec, &mut rng);
    let ckpt = qera::model::Checkpoint::new(spec.clone(), params);
    let calib = CalibResult::synthetic(&spec, 192, 7);
    let fmt = QFormat::Mxint { bits: 3, block: 32 };
    // backends as columns (baseline exact first, shipped randomized last)
    // so the bench gate's last-p50-column median tracks the shipped path
    let mut t = Table::new(
        "per-model solve wall time (12 layers, nano, rank 8)",
        &["method", "exact total ms p50", "randomized total ms p50"],
    );
    let rand = SvdBackend::Randomized {
        oversample: SvdBackend::DEFAULT_OVERSAMPLE,
        power_iters: SvdBackend::DEFAULT_POWER_ITERS,
    };
    for method in [Method::ZeroQuantV2, Method::Lqer, Method::QeraApprox, Method::QeraExact] {
        let iters = if smoke() { 2 } else { 3 };
        let p50_of = |svd: SvdBackend| {
            let s = time_stats(1, iters, || {
                let cfg = PipelineConfig::new(method, fmt, 8).with_svd(svd);
                std::hint::black_box(quantize(&ckpt, &cfg, Some(&calib)).unwrap());
            });
            s.p50_ms
        };
        let exact_ms = p50_of(SvdBackend::Exact);
        let rand_ms = p50_of(rand);
        t.row(vec![method.name(), f4(exact_ms), f4(rand_ms)]);
    }
    t.emit("hot_solver");
    t
}

fn bench_forward(reg: &Registry) -> anyhow::Result<()> {
    let spec = reg.spec("nano")?.clone();
    let mut rng = Rng::new(3);
    let params = qera::model::init::init_params(&spec, &mut rng);
    let tokens: Vec<i32> =
        (0..spec.batch * spec.seq).map(|_| rng.below(spec.vocab) as i32).collect();
    let shape = [spec.batch, spec.seq];
    let mut t = Table::new(
        "PJRT forward latency (nano)",
        &["artifact", "p50 ms", "p95 ms", "tok/s"],
    );
    for name in ["lm_fwd.nano", "lm_nll.nano", "lm_logits_last.nano", "lm_fwd_taps.nano"] {
        let exec = reg.load(name)?;
        let needs_targets = exec.info.inputs.iter().any(|i| i.name == "targets");
        let s = time_stats(2, 20, || {
            let inputs = if needs_targets {
                lm_inputs(&tokens, Some((&tokens, &shape)), &shape, &params)
            } else {
                lm_inputs(&tokens, None, &shape, &params)
            };
            std::hint::black_box(exec.run(&inputs).unwrap());
        });
        let toks = (spec.batch * spec.seq) as f64 / (s.p50_ms / 1e3);
        t.row(vec![name.to_string(), f2(s.p50_ms), f2(s.p95_ms), format!("{toks:.0}")]);
    }

    // fused low-rank serving form vs dense (the no-overhead claim)
    let exec_lr = reg.load("lm_fwd_lr.nano.r8")?;
    let lora: Vec<Tensor> = spec
        .lora_layout(8)
        .into_iter()
        .map(|(_, shape)| Tensor::randn(shape, 0.01, &mut rng))
        .collect();
    let s = time_stats(2, 20, || {
        let mut inputs = lm_inputs(&tokens, None, &shape, &params);
        inputs.extend(lora.iter().cloned().map(qera::runtime::Value::from));
        std::hint::black_box(exec_lr.run(&inputs).unwrap());
    });
    let toks = (spec.batch * spec.seq) as f64 / (s.p50_ms / 1e3);
    t.row(vec![
        "lm_fwd_lr.nano.r8 (A,B separate)".into(),
        f2(s.p50_ms),
        f2(s.p95_ms),
        format!("{toks:.0}"),
    ]);
    t.emit("hot_forward");
    Ok(())
}

/// Calibration `R_XX` fold: the seed scalar triple loop (per-element
/// f32→f64 casts) vs the blocked SYRK kernel, serial and auto-threaded —
/// the streaming-statistics ingest behind every QERA-exact calibration
/// site.  The m=1024 row is the tentpole target: the threaded fold should
/// beat the scalar loop by ≥ 4x with 8 workers.
fn bench_calib() -> Table {
    let mut t = Table::new(
        "calib: rxx fold, seed scalar loop vs blocked SYRK (ms)",
        &["rows x dim", "scalar p50", "blocked serial p50", "blocked auto p50", "speedup"],
    );
    let mut rng = Rng::new(7);
    let shapes: &[(usize, usize)] =
        if smoke() { &[(128, 256)] } else { &[(256, 256), (256, 1024)] };
    for &(rows, m) in shapes {
        let x = Tensor::randn(vec![rows, m], 1.0, &mut rng);
        let iters = if smoke() {
            2
        } else if m >= 1024 {
            3
        } else {
            5
        };
        let scalar = time_stats(1, iters, || {
            // the seed kernel: scalar triple loop, f32→f64 cast per element
            let data = x.data();
            let mut sum_abs = vec![0.0f64; m];
            let mut sum_sq = vec![0.0f64; m];
            let mut rxx = vec![0.0f64; m * m];
            for r in 0..rows {
                let row = &data[r * m..(r + 1) * m];
                for (i, &v) in row.iter().enumerate() {
                    let v = v as f64;
                    sum_abs[i] += v.abs();
                    sum_sq[i] += v * v;
                }
                for i in 0..m {
                    let vi = row[i] as f64;
                    if vi == 0.0 {
                        continue;
                    }
                    let dst = &mut rxx[i * m..(i + 1) * m];
                    for j in i..m {
                        dst[j] += vi * row[j] as f64;
                    }
                }
            }
            std::hint::black_box((sum_abs, sum_sq, rxx));
        });
        let serial = time_stats(1, iters, || {
            let mut st = CalibStats::new(m, true);
            st.update_workers(&x, 1);
            std::hint::black_box(st);
        });
        let auto = time_stats(1, iters, || {
            let mut st = CalibStats::new(m, true);
            st.update(&x);
            std::hint::black_box(st);
        });
        t.row(vec![
            format!("{rows}x{m}"),
            f3(scalar.p50_ms),
            f3(serial.p50_ms),
            f3(auto.p50_ms),
            f2(scalar.p50_ms / auto.p50_ms),
        ]);
    }
    t.emit("hot_calib");
    t
}

/// Budget planner hot path: one layer's candidate-grid profiling (the
/// layer × cell solve loop behind `budget::profile`) and the allocator
/// sweeps over a 16-layer synthetic model, at widths m ∈ {256, 1024}
/// (smoke: 256 only).  Column order puts the heavy profile pass last so
/// the bench gate tracks it.
fn bench_budget() -> Table {
    use qera::budget::{allocate, score_layer, AllocStrategy, BudgetProfile, CandidateGrid};
    let mut t = Table::new(
        "budget: layer x cell profile + allocator sweeps (ms)",
        &["m", "alloc greedy p50", "alloc lagrangian p50", "profile p50"],
    );
    let grid = CandidateGrid::default_ptq();
    let shapes: &[usize] = if smoke() { &[256] } else { &[256, 1024] };
    for &m in shapes {
        let mut rng = Rng::new(m as u64);
        let w = Tensor::randn(vec![m, m], 1.0, &mut rng);
        let rows = 2 * m.min(256);
        let x = Tensor::randn(vec![rows, m], 1.0, &mut rng);
        let mut stats = CalibStats::new(m, true);
        stats.update(&x);
        let rxx = stats.rxx_mean().unwrap();
        let cfg = PipelineConfig::new(
            Method::QeraExact,
            QFormat::Mxint { bits: 4, block: 32 },
            8,
        );
        let iters = if smoke() {
            2
        } else if m >= 1024 {
            2
        } else {
            3
        };
        let prof_s = time_stats(1, iters, || {
            std::hint::black_box(score_layer("bench", &w, &stats, &rxx, &cfg, 0, &grid).unwrap());
        });
        // allocator timing over a 16-layer model built from the scored layer
        let layer = score_layer("bench", &w, &stats, &rxx, &cfg, 0, &grid).unwrap();
        let prof = BudgetProfile {
            model: "bench".into(),
            method: Method::QeraExact,
            svd: SvdBackend::Auto,
            psd: qera::solver::PsdBackend::Auto,
            layers: (0..16)
                .map(|i| {
                    let mut l = layer.clone();
                    l.name = format!("blk{i:02}.w");
                    l
                })
                .collect(),
        };
        let greedy_s = time_stats(1, iters * 10, || {
            std::hint::black_box(allocate(&prof, 3.75, AllocStrategy::Greedy).unwrap());
        });
        let lag_s = time_stats(1, iters * 10, || {
            std::hint::black_box(allocate(&prof, 3.75, AllocStrategy::Lagrangian).unwrap());
        });
        t.row(vec![
            m.to_string(),
            f4(greedy_s.p50_ms),
            f4(lag_s.p50_ms),
            f3(prof_s.p50_ms),
        ]);
    }
    t.emit("hot_budget");
    t
}

/// Quantize-dequantize kernels: serial vs pool-threaded block chunks (the
/// per-layer `q(W)` inside every solve and checkpoint materialization).
fn bench_qdq() -> Table {
    let mut t = Table::new(
        "qdq: quantizer kernels, serial vs threaded block chunks (ms)",
        &["format", "serial p50", "auto p50", "speedup"],
    );
    let mut rng = Rng::new(8);
    let (r, c) = if smoke() { (256, 512) } else { (1024, 2048) };
    let w = Tensor::randn(vec![r, c], 0.05, &mut rng);
    let iters = if smoke() { 3 } else { 5 };
    for fmt in [
        QFormat::Mxint { bits: 4, block: 32 },
        QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
        QFormat::Fp4 { group: 64 },
    ] {
        let serial = time_stats(1, iters, || {
            std::hint::black_box(fmt.qdq_workers(&w, 1));
        });
        let auto = time_stats(1, iters, || {
            std::hint::black_box(fmt.qdq(&w));
        });
        t.row(vec![
            fmt.name(),
            f3(serial.p50_ms),
            f3(auto.p50_ms),
            f2(serial.p50_ms / auto.p50_ms),
        ]);
    }
    t.emit("hot_qdq");
    t
}

/// Fused quantized execution vs dequantize-then-matmul: the serve /
/// eval-ppl hot path on the native backend, `y = x·W_q (+ (x·A)·B)` from
/// packed blocks.  The fused column is the shipped path (last p50 — the CI
/// gate watches it); the reference materializes the dense `[k,n]` f32
/// weight per call.
fn bench_exec() -> Table {
    use qera::quant::{exec as qexec, PackedWeight};
    let mut t = Table::new(
        "exec: fused-from-packed vs dequantize-then-matmul (ms)",
        &["fmt m k n rank", "dequant+mm p50", "fused p50", "speedup"],
    );
    let mut rng = Rng::new(9);
    let (k, n) = (512usize, 512usize);
    let ms: &[usize] = if smoke() { &[256] } else { &[256, 1024] };
    let iters = if smoke() { 3 } else { 5 };
    for fmt in [
        QFormat::Mxint { bits: 4, block: 32 },
        QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
        QFormat::Fp4 { group: 64 },
    ] {
        let w = Tensor::randn(vec![k, n], 0.05, &mut rng);
        let pw = PackedWeight::quantize(w.data(), &fmt).expect("packable format");
        for &m in ms {
            let x = Tensor::randn(vec![m, k], 1.0, &mut rng);
            for rank in [0usize, 16] {
                let lr = (rank > 0).then(|| {
                    (
                        Tensor::randn(vec![k, rank], 0.02, &mut rng),
                        Tensor::randn(vec![rank, n], 0.02, &mut rng),
                    )
                });
                let lr_ref = lr.as_ref().map(|(a, b)| (a, b));
                let dq = time_stats(1, iters, || {
                    std::hint::black_box(qexec::dequant_matmul_ref(&x, &pw, k, n, lr_ref));
                });
                let fused = time_stats(1, iters, || {
                    std::hint::black_box(qexec::fused_matmul(&x, &pw, k, n, lr_ref));
                });
                t.row(vec![
                    format!("{} {m}x{k}x{n} r{rank}", fmt.name()),
                    f3(dq.p50_ms),
                    f3(fused.p50_ms),
                    f2(dq.p50_ms / fused.p50_ms),
                ]);
            }
        }
    }
    t.emit("hot_exec");
    t
}

/// Checkpoint I/O: the sharded-manifest path (streamed shard writes with
/// per-shard sha256, then the parallel verified reload behind
/// `model::open`) against the monolithic single-file load, plus the
/// resume-journal scan a crashed `--resume` run pays before any solving
/// starts.  The verified sharded load is the shipped serve / eval
/// cold-start path (last p50 — the CI gate watches it, and every "resume
/// scan" p95).
fn bench_ckpt() -> Table {
    use qera::model::shard::param_groups;
    use qera::model::{CkptKind, ShardParam, ShardWriter};
    use qera::util::fsio::StdIo;
    use qera::util::retry::RetryPolicy;
    use std::sync::Arc;
    let mut t = Table::new(
        "ckpt: monolithic vs sharded manifest I/O (ms)",
        &["m", "shard write p50", "mono load p50", "resume scan p50", "sharded verified load p50"],
    );
    let dir = std::env::temp_dir().join("qera_bench_ckpt");
    std::fs::create_dir_all(&dir).expect("bench tmpdir");
    let ms: &[usize] = if smoke() { &[256] } else { &[256, 1024] };
    for &m in ms {
        let spec = ModelSpec {
            name: format!("bench{m}"),
            vocab: 256,
            d_model: m,
            n_layers: 2,
            n_heads: 4,
            d_ff: 2 * m,
            seq: 32,
            batch: 2,
            n_classes: 2,
        };
        let mut rng = Rng::new(m as u64);
        let params = qera::model::init::init_params(&spec, &mut rng);
        let ckpt = qera::model::Checkpoint::new(spec, params);
        let mono = dir.join(format!("bench{m}.qkpt"));
        let manifest = dir.join(format!("bench{m}.manifest.json"));
        ckpt.save(&mono).expect("monolithic save");
        let iters = if smoke() || m >= 1024 { 3 } else { 5 };
        let write = time_stats(1, iters, || {
            std::hint::black_box(ckpt.save_sharded(&manifest, 1).expect("shard write"));
        });
        let mono_load = time_stats(1, iters, || {
            let back = qera::model::open(&mono).and_then(|r| r.into_dense());
            std::hint::black_box(back.expect("monolithic load"));
        });
        // a crashed streaming run: every shard written and journaled, the
        // manifest never landed — resume() re-reads the journal and
        // re-verifies each shard's size + sha256 on disk
        let jman = dir.join(format!("bench{m}-crash.manifest.json"));
        {
            let layout = ckpt.spec.param_layout();
            let mut w =
                ShardWriter::create(&jman, CkptKind::Dense, ckpt.spec.clone(), ckpt.meta.clone())
                    .expect("journaled writer");
            for group in param_groups(&ckpt.spec, 1) {
                let entries = group
                    .iter()
                    .map(|&i| (layout[i].0.clone(), ShardParam::Dense(ckpt.params[i].clone())))
                    .collect();
                w.write_shard(entries).expect("journaled shard write");
            }
            // no finish(): the journal stays behind, as after a crash
        }
        let resume_scan = time_stats(1, iters, || {
            let (_, verified) = ShardWriter::resume(
                &jman,
                CkptKind::Dense,
                ckpt.spec.clone(),
                ckpt.meta.clone(),
                Arc::new(StdIo),
                RetryPolicy::io_default(),
            )
            .expect("resume scan");
            std::hint::black_box(verified.len());
        });
        let shard_load = time_stats(1, iters, || {
            let back = qera::model::open(&manifest).and_then(|r| r.into_dense());
            std::hint::black_box(back.expect("sharded verified load"));
        });
        t.row(vec![
            m.to_string(),
            f3(write.p50_ms),
            f3(mono_load.p50_ms),
            f3(resume_scan.p50_ms),
            f3(shard_load.p50_ms),
        ]);
    }
    let _ = std::fs::remove_dir_all(&dir);
    t.emit("hot_ckpt");
    t
}

fn bench_quant() {
    let mut rng = Rng::new(4);
    let w = Tensor::randn(vec![512, 512], 0.02, &mut rng);
    let mut t =
        Table::new("quantizer throughput (512x512 weight)", &["format", "p50 ms", "Melem/s"]);
    for fmt in [
        QFormat::Mxint { bits: 4, block: 32 },
        QFormat::Mxint { bits: 2, block: 16 },
        QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
        QFormat::Fp4 { group: 64 },
    ] {
        let s = time_stats(1, 10, || {
            std::hint::black_box(fmt.qdq(&w));
        });
        t.row(vec![
            fmt.name(),
            f3(s.p50_ms),
            format!("{:.1}", 512.0 * 512.0 / 1e6 / (s.p50_ms / 1e3)),
        ]);
    }
    t.emit("hot_quant");
}

fn bench_stats() {
    let mut rng = Rng::new(5);
    let x = Tensor::randn(vec![256, 256], 1.0, &mut rng);
    let mut t = Table::new(
        "calibration accumulation (256 rows x 256 dims)",
        &["mode", "p50 ms"],
    );
    let s1 = time_stats(1, 10, || {
        let mut st = CalibStats::new(256, true);
        st.update(&x);
        std::hint::black_box(st);
    });
    let s2 = time_stats(1, 10, || {
        let mut st = CalibStats::new(256, false);
        st.update(&x);
        std::hint::black_box(st);
    });
    t.row(vec!["with R_XX".into(), f2(s1.p50_ms)]);
    t.row(vec!["diag only".into(), f2(s2.p50_ms)]);
    t.emit("hot_stats");
}

fn bench_serve() -> anyhow::Result<Table> {
    use std::time::Duration;
    // native backend: artifact-free, so this group always lands in the JSON
    // report and the CI tail gate (the SLO gate — p50 AND p95 columns)
    let spec = ModelSpec::builtin("nano").expect("builtin spec");
    let mut rng = Rng::new(6);
    let params = qera::model::init::init_params(&spec, &mut rng);
    let (n_req, n_tok) = if smoke() { (4usize, 4usize) } else { (16, 8) };
    let mut t = Table::new(
        "serving daemon: throughput + latency tails vs batching window (native backend)",
        &[
            "max-wait ms",
            "admitted",
            "tok/s",
            "mean batch",
            "queue p50 ms",
            "queue p95 ms",
            "total p50 ms",
            "total p95 ms",
            "shed",
            "restarts",
            "swaps",
        ],
    );
    for wait_ms in [0u64, 10, 50] {
        let server = qera::serve::Server::start(
            std::path::PathBuf::from("bench-unused-artifacts"),
            spec.clone(),
            params.clone(),
            qera::serve::ServerConfig {
                max_wait: Duration::from_millis(wait_ms),
                seed: 1,
                backend: qera::runtime::ExecBackend::Native,
                ..Default::default()
            },
        );
        let handles: Vec<_> =
            (0..n_req).map(|i| server.submit(vec![i as i32 + 1, 2], n_tok, 0.0)).collect();
        for h in handles {
            h.map_err(|e| anyhow::anyhow!("bench submit rejected: {e}"))?
                .wait_timeout(Duration::from_secs(300))
                .ok_or_else(|| anyhow::anyhow!("bench request still in flight after 300s"))?
                .response()?;
        }
        let stats = server.stop()?;
        t.row(vec![
            wait_ms.to_string(),
            stats.admitted.to_string(),
            format!("{:.1}", stats.throughput_tok_s()),
            f2(stats.mean_batch()),
            f2(stats.queue_p50_ms()),
            f2(stats.queue_p95_ms()),
            f2(stats.total_p50_ms()),
            f2(stats.total_p95_ms()),
            stats.shed.to_string(),
            stats.engine_restarts.to_string(),
            stats.swaps.to_string(),
        ]);
    }
    t.emit("hot_serve");
    Ok(t)
}

/// Per-site overhead of the observability layer.  The tentpole invariant
/// is the disabled fast path: with tracing off, a span call site must cost
/// one relaxed atomic load (no allocation, no lock) — the `ns/op p50`
/// column lands in the CI bench gate so the hot paths never silently grow
/// instrumentation cost.  Metric rows measure the cached-handle hot path
/// (the statics every instrumented module keeps), not registration.
fn bench_obs() -> Table {
    use qera::obs::{metrics, trace};
    // a stray QERA_TRACE must not turn the disabled-path rows into live ones
    trace::global().disable();
    let n = if smoke() { 100_000u64 } else { 1_000_000 };
    let per_ns = |ms: f64, ops: u64| format!("{:.2}", ms * 1e6 / ops as f64);
    let mut t = Table::new(
        "obs: per-site overhead, tracing disabled vs enabled (ns/op)",
        &["op", "ns/op p50"],
    );
    let off = time_stats(1, 5, || {
        for _ in 0..n {
            std::hint::black_box(trace::span("obs.bench.span"));
        }
    });
    t.row(vec!["span (tracing off)".into(), per_ns(off.p50_ms, n)]);
    let off_s = time_stats(1, 5, || {
        for _ in 0..n {
            std::hint::black_box(trace::sample_span("obs.bench.sampled", 64));
        }
    });
    t.row(vec!["sample_span (tracing off)".into(), per_ns(off_s.p50_ms, n)]);
    trace::global().enable();
    let m = n / 100;
    let on = time_stats(1, 3, || {
        for _ in 0..m {
            std::hint::black_box(trace::span("obs.bench.span"));
        }
        trace::global().reset();
    });
    trace::global().disable();
    t.row(vec!["span (tracing on, buffered)".into(), per_ns(on.p50_ms, m)]);
    let c = metrics::counter("qera_obs_bench_total", &[]);
    let ct = time_stats(1, 5, || {
        for _ in 0..n {
            c.inc();
        }
    });
    t.row(vec!["counter inc (cached handle)".into(), per_ns(ct.p50_ms, n)]);
    let g = metrics::gauge("qera_obs_bench_gauge", &[]);
    let gt = time_stats(1, 5, || {
        for i in 0..n {
            g.set(i as i64);
        }
    });
    t.row(vec!["gauge set (cached handle)".into(), per_ns(gt.p50_ms, n)]);
    let h = metrics::histogram("qera_obs_bench_ms", &[], metrics::LATENCY_MS_BUCKETS);
    let ht = time_stats(1, 5, || {
        for i in 0..n {
            h.observe((i % 7) as f64);
        }
    });
    t.row(vec!["histogram observe (cached handle)".into(), per_ns(ht.p50_ms, n)]);
    t.emit("hot_obs");
    t
}

fn main() -> anyhow::Result<()> {
    // cargo bench passes harness flags like `--bench`; keep only filters
    let args: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    // exact group-name matching: substring filters made "matmul" and
    // "tensor_matmul" inseparable
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a.as_str() == name);
    println!("== hotpath microbenchmarks ==");
    if want("eigh") {
        bench_eigh();
    }
    let mut report: Vec<(&str, Table)> = Vec::new();
    if want("svd") {
        report.push(("svd", bench_svd()));
    }
    if want("matmul") {
        report.push(("matmul", bench_matmul()));
    }
    if want("tensor_matmul") || want("tensor") {
        report.push(("tensor_matmul", bench_tensor_matmul()));
    }
    if want("psd") {
        report.push(("psd", bench_psd()));
    }
    if want("solver") {
        report.push(("solver", bench_solver()));
    }
    if want("calib") {
        report.push(("calib", bench_calib()));
    }
    if want("qdq") {
        report.push(("qdq", bench_qdq()));
    }
    if want("budget") {
        report.push(("budget", bench_budget()));
    }
    if want("exec") {
        report.push(("exec", bench_exec()));
    }
    if want("serve") {
        report.push(("serve", bench_serve()?));
    }
    if want("ckpt") {
        report.push(("ckpt", bench_ckpt()));
    }
    if want("obs") {
        report.push(("obs", bench_obs()));
    }
    if want("quant") {
        bench_quant();
    }
    if want("stats") {
        bench_stats();
    }
    if !report.is_empty() {
        // record the bench profile so check_bench can refuse to diff a
        // smoke-mode run against a full-mode baseline (different shapes)
        let mut mode = Table::new("bench mode", &["mode"]);
        mode.row(vec![if smoke() { "smoke".into() } else { "full".into() }]);
        report.push(("_mode", mode));
        let refs: Vec<(&str, &Table)> = report.iter().map(|(k, t)| (*k, t)).collect();
        emit_json_report("BENCH_solver.json", &refs);
    }
    // PJRT-backed groups only run when the artifacts are built
    if want("forward") {
        match Registry::open_default() {
            Ok(reg) => bench_forward(&reg)?,
            Err(e) => println!("[skip] PJRT benches (no artifacts): {e:#}"),
        }
    }
    Ok(())
}
