//! Offline stub of the `xla` (PJRT) crate.
//!
//! Provides the exact API surface `qera::runtime` compiles against, so the
//! workspace builds (and the pure-Rust solver/linalg/serving stack runs)
//! without the XLA C library.  Every device operation fails at runtime with
//! a clear message; artifact-gated tests and benches detect the missing
//! `artifacts/` directory and skip before ever reaching these calls.
//!
//! To enable real PJRT execution, point the `xla` path dependency in the
//! workspace `Cargo.toml` at the real xla crate (LaurentMazare/xla-rs) with
//! its PJRT plugin available.

use std::fmt;
use std::path::Path;

const STUB_MSG: &str = "PJRT unavailable: built against the vendored `xla` stub \
(rust/vendor/xla); swap the path dependency for the real xla crate to execute \
HLO artifacts";

/// Stub error type (string-backed).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the runtime marshals (subset of XLA's).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    F32,
    F64,
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Parsed HLO module (stub: validates the file exists, retains nothing).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        std::fs::metadata(path.as_ref())
            .map_err(|e| Error::new(format!("{}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { _priv: () })
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Stub CPU client: constructible (so process setup and thread-local client
/// caching work) but refuses to compile.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_MSG))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(STUB_MSG))
    }
}

/// Host literal (stub: shape/data are discarded at construction).
#[derive(Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new(STUB_MSG))
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::new(STUB_MSG))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_compile_fails() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.device_count(), 1);
        assert_eq!(c.platform_name(), "stub-cpu");
        let proto = HloModuleProto { _priv: () };
        let comp = XlaComputation::from_proto(&proto);
        assert!(c.compile(&comp).is_err());
    }

    #[test]
    fn literal_roundtrip_surface() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        let l2 = l.reshape(&[2, 1]).unwrap();
        assert!(l2.ty().is_err());
        assert!(l2.to_vec::<f32>().is_err());
        assert!(l2.to_tuple().is_err());
    }

    #[test]
    fn missing_hlo_file_is_an_error() {
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo.txt").is_err());
    }
}
