//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the API subset qera uses — [`Error`], [`Result`],
//! the [`Context`] extension trait (on `Result` *and* `Option`, including
//! results that already carry an [`Error`]), and the `anyhow!` / `bail!` /
//! `ensure!` macros — with the same semantics: `Display` shows the
//! outermost context, `{:#}` joins the whole chain, `Debug` renders a
//! "Caused by" list.  No external dependencies, so the workspace builds
//! without a crates.io registry.

use std::fmt::{self, Debug, Display};

/// Context-chain error value.  Deliberately does **not** implement
/// `std::error::Error` (mirroring the real anyhow) so the blanket
/// `From<E: std::error::Error>` impl below stays coherent.
pub struct Error {
    /// Messages, outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    fn push_context<C: Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// Innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate messages from the outermost context to the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;

    /// Conversion into [`Error`] for both std errors and `Error` itself —
    /// the same coherence arrangement the real anyhow uses (`Error` does
    /// not implement `std::error::Error`, so the impls are disjoint).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to failures, exactly like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_error().push_context(ctx))
    }
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into_error().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// `bail!("fmt {args}")` — early-return an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt {args}")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Source-free std error (io::Error::new exposes its payload through
    /// `source()`, which would double-count chain entries in these tests).
    #[derive(Debug)]
    struct Gone;
    impl fmt::Display for Gone {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("gone")
        }
    }
    impl std::error::Error for Gone {}

    fn io_err() -> Gone {
        Gone
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "gone");
        let with = Result::<(), _>::Err(io_err()).context("outer").unwrap_err();
        assert_eq!(with.to_string(), "outer");
        assert_eq!(format!("{with:#}"), "outer: gone");
        assert!(format!("{with:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("wrapped").unwrap_err();
        assert_eq!(e.to_string(), "wrapped");
        assert_eq!(e.root_cause(), "inner 7");

        let none: Option<u32> = None;
        let e2 = none.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e2.to_string(), "missing x");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "flag was {ok}");
            Ok(1)
        }
        fn g() -> Result<u32> {
            bail!("nope {}", 2);
        }
        fn h(ok: bool) -> Result<u32> {
            ensure!(ok);
            Ok(4)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(g().unwrap_err().to_string(), "nope 2");
        assert!(h(false).unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn chain_walks_sources() {
        let e: Error = io_err().into();
        assert_eq!(e.chain().count(), 1);
        let wrapped = Result::<(), _>::Err(io_err()).context("a").unwrap_err();
        let msgs: Vec<&str> = wrapped.chain().collect();
        assert_eq!(msgs, vec!["a", "gone"]);
    }
}
