//! Quickstart: quantize a model with QERA and measure what it buys you.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Steps: pretrain a nano LM on the synthetic corpus (~30 s), calibrate
//! activation statistics, quantize to 3.25-bit MXINT with and without
//! QERA's low-rank reconstruction, and compare perplexity.

use qera::coordinator::{calibrate, quantize, PipelineConfig};
use qera::data::Corpus;
use qera::eval::perplexity;
use qera::quant::QFormat;
use qera::runtime::Registry;
use qera::solver::Method;
use qera::train::{pretrain, PretrainConfig};

fn main() -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    let spec = reg.spec("nano")?.clone();
    println!("model: {} ({:.2}M params)", spec.name, spec.n_params() as f64 / 1e6);

    // 1. a pretrained subject model (the paper starts from pretrained LLMs)
    let corpus = Corpus::generate(spec.vocab, 200_000, 42);
    let (train, val) = corpus.split(0.1);
    let pcfg = PretrainConfig { steps: 1500, lr: 2e-3, warmup: 30, seed: 42, log_every: 300 };
    let (ckpt, report) = pretrain(&reg, &spec, &train, &pcfg)?;
    let bf16_ppl = perplexity(&reg, &spec, &ckpt.params, &val, 8)?;
    println!("pretrained: loss {:.3}, val ppl {:.3}", report.final_loss, bf16_ppl);

    // 2. calibration (Theorem 2 needs E[x²]; Theorem 1 needs R_XX)
    let calib = calibrate(&reg, &spec, &ckpt.params, &train, 16, true)?;

    // 3. quantize at 2.50 bits, rank 16 — aggressive enough that the
    //    methods separate (paper Table 3's 3-bit regime)
    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    for method in [Method::WOnly, Method::ZeroQuantV2, Method::QeraApprox, Method::QeraExact] {
        let qm = quantize(&ckpt, &PipelineConfig::new(method, fmt, 16), Some(&calib))?;
        let ppl = perplexity(&reg, &spec, &qm.merged, &val, 8)?;
        println!(
            "{:<14} {:>7.3} ppl  (Δ {:+.3}, {:.2} eff. bits)",
            method.name(),
            ppl,
            ppl - bf16_ppl,
            qm.effective_bits()
        );
    }
    println!("\nExpected ordering: w-only > zeroquant-v2 > qera-approx >= qera-exact.");
    Ok(())
}
