//! PTQ pipeline (the paper's Table 3 workflow, end to end).
//!
//! Pretrains the subject LM, then runs the full method grid at two
//! precisions (4.25 and 3.25 W-bits), evaluates WikiText2-analog perplexity
//! plus the Figure-4 win rate, and writes the quantized checkpoints —
//! including the bit-packed on-disk form — under `results/`.
//!
//! ```bash
//! cargo run --release --example ptq_pipeline            # nano, quick
//! QERA_MODEL=small cargo run --release --example ptq_pipeline
//! QERA_SVD=exact cargo run --release --example ptq_pipeline   # force exact SVD
//! QERA_PSD=exact cargo run --release --example ptq_pipeline   # force exact R½
//! QERA_BUDGET_BITS=3.5 cargo run --release --example ptq_pipeline  # budget target
//! ```
//!
//! `QERA_SVD` selects the solver SVD backend (`auto` | `exact` |
//! `randomized[:oversample[:power_iters]]`); the default `auto` takes the
//! randomized fast path whenever `rank * 4 <= min(m, n)`.  `QERA_PSD`
//! selects QERA-exact's `(R^{1/2}, R^{-1/2})` backend (`auto` | `exact` |
//! `lowrank[:rank_mult[:power_iters]]`); the default `auto` takes the
//! low-rank + diagonal split whenever the rank is small relative to the
//! layer width.

use qera::bench_util::Table;
use qera::budget::{allocate, profile, AllocStrategy, BudgetPlan, CandidateGrid};
use qera::coordinator::{calibrate, quantize, PipelineConfig};
use qera::data::Corpus;
use qera::eval::{perplexity, win_rate};
use qera::quant::QFormat;
use qera::runtime::Registry;
use qera::solver::{Method, PsdBackend, SvdBackend};
use qera::train::{pretrain, PretrainConfig};

fn main() -> anyhow::Result<()> {
    let model = std::env::var("QERA_MODEL").unwrap_or_else(|_| "nano".into());
    let steps: usize =
        std::env::var("QERA_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(2500);
    let svd = match std::env::var("QERA_SVD") {
        Ok(s) => SvdBackend::parse(&s)?,
        Err(_) => SvdBackend::Auto,
    };
    let psd = match std::env::var("QERA_PSD") {
        Ok(s) => PsdBackend::parse(&s)?,
        Err(_) => PsdBackend::Auto,
    };
    println!("svd backend: {}, psd backend: {}", svd.name(), psd.name());
    let reg = Registry::open_default()?;
    let spec = reg.spec(&model)?.clone();

    let corpus = Corpus::generate(spec.vocab, 400_000, 42);
    let (train, val) = corpus.split(0.05);
    let pcfg = PretrainConfig { steps, lr: 2e-3, warmup: 20, seed: 42, log_every: 50 };
    let (ckpt, _) = pretrain(&reg, &spec, &train, &pcfg)?;
    let bf16_ppl = perplexity(&reg, &spec, &ckpt.params, &val, 8)?;
    println!("BF16 reference ppl: {bf16_ppl:.3}");

    let calib = calibrate(&reg, &spec, &ckpt.params, &train, 16, true)?;
    std::fs::create_dir_all("results")?;

    for (fmt, rank) in [
        (QFormat::Mxint { bits: 3, block: 32 }, 8usize),
        (QFormat::Mxint { bits: 2, block: 16 }, 16),
    ] {
        let mut table = Table::new(
            &format!("PTQ {} @ {:.2} W-bits, rank {rank}", spec.name, fmt.avg_bits()),
            &["method", "ppl", "delta", "win-rate-vs-wonly", "payload MB"],
        );
        table.row(vec![
            "bf16".into(),
            format!("{bf16_ppl:.3}"),
            "-".into(),
            "-".into(),
            format!("{:.2}", (spec.n_params() * 4) as f64 / 1e6),
        ]);
        let wonly = quantize(
            &ckpt,
            &PipelineConfig::new(Method::WOnly, fmt, 0).with_svd(svd).with_psd(psd),
            Some(&calib),
        )?;
        // sharded manifest round-trip: record-identical bytes per layer,
        // plus per-shard sha256 verification on the parallel reload
        let manifest = wonly
            .ckpt
            .save_sharded(format!("results/{}-wonly.manifest.json", spec.name), 1)?;
        let back = qera::model::open(&manifest)?.into_quant()?;
        assert_eq!(back.materialize_merged(), wonly.merged, "sharded round-trip");
        for method in Method::ptq_grid() {
            let r = if method == Method::WOnly { 0 } else { rank };
            let qm = quantize(
                &ckpt,
                &PipelineConfig::new(method, fmt, r).with_svd(svd).with_psd(psd),
                Some(&calib),
            )?;
            let ppl = perplexity(&reg, &spec, &qm.merged, &val, 8)?;
            let wr = if method == Method::WOnly {
                0.5
            } else {
                win_rate(&reg, &spec, &ckpt.params, &qm.merged, &wonly.merged, &val, 4)?
            };
            // persist the quantized checkpoint and reload to prove the
            // bit-packed MXINT round-trip
            let path = format!(
                "results/{}-{}-{}.qqkpt",
                spec.name,
                fmt.name().replace(':', "_"),
                method.name().replace(':', "_")
            );
            qm.ckpt.save(&path)?;
            let back = qera::model::open(&path)?.into_quant()?;
            assert_eq!(back.materialize_merged(), qm.merged, "checkpoint round-trip");
            table.row(vec![
                method.name(),
                format!("{ppl:.3}"),
                format!("{:+.3}", ppl - bf16_ppl),
                format!("{wr:.3}"),
                format!("{:.2}", qm.ckpt.payload_bytes() as f64 / 1e6),
            ]);
        }
        table.emit(&format!("ptq_{}_{}", spec.name, fmt.name().replace(':', "_")));
    }

    // Budget-aware mixed precision: profile every layer x (format, rank)
    // cell once, then compare allocation strategies at one matched
    // bits/weight budget (`QERA_BUDGET_BITS`, default 3.75) — including
    // the plan-artifact round trip the CLI exposes as --plan-out/--plan-in.
    let budget_bits: f64 = std::env::var("QERA_BUDGET_BITS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3.75);
    let base = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 4, block: 32 }, 8)
        .with_svd(svd)
        .with_psd(psd);
    let prof = profile(&ckpt, &calib, &base, &CandidateGrid::default_ptq())?;
    let mut table = Table::new(
        &format!("budget plans {} @ {budget_bits:.2} bits/weight", spec.name),
        &["strategy", "achieved-bits", "pred-error", "ppl", "delta"],
    );
    for strat in AllocStrategy::all() {
        let plan = allocate(&prof, budget_bits, strat)?;
        let path = format!("results/{}-plan-{}.json", spec.name, strat.name());
        plan.save(&path)?;
        let reloaded = BudgetPlan::load(&path)?;
        assert_eq!(reloaded, plan, "plan artifact round-trip");
        let qm = quantize(&ckpt, &base.clone().with_plan(reloaded), Some(&calib))?;
        let ppl = perplexity(&reg, &spec, &qm.merged, &val, 8)?;
        table.row(vec![
            strat.name(),
            format!("{:.3}", qm.effective_bits()),
            format!("{:.4}", plan.total_error),
            format!("{ppl:.3}"),
            format!("{:+.3}", ppl - bf16_ppl),
        ]);
    }
    table.emit(&format!("budget_{}", spec.name));
    Ok(())
}
