//! QPEFT fine-tuning (the paper's Table 1 workflow): initialize LoRA
//! adapters of a 2.5-bit quantized model with QLoRA / LoftQ / QERA-approx
//! and fine-tune on a GLUE-analog task — QERA's better initialization shows
//! up as higher accuracy and faster convergence (Figure 2).
//!
//! ```bash
//! cargo run --release --example qpeft_finetune
//! QERA_TASK=pattern QERA_EPOCHS=10 cargo run --release --example qpeft_finetune
//! ```

use qera::bench_util::Table;
use qera::coordinator::calibrate;
use qera::data::tasks::Task;
use qera::data::Corpus;
use qera::quant::QFormat;
use qera::runtime::Registry;
use qera::solver::Method;
use qera::train::lora::{lora_init, LoraClsTrainer};
use qera::train::{pretrain, PretrainConfig};
use qera::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let task_name = std::env::var("QERA_TASK").unwrap_or_else(|_| "majority".into());
    let epochs: usize =
        std::env::var("QERA_EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);
    let reg = Registry::open_default()?;
    let spec = reg.spec("nano")?.clone();
    let task = Task::by_name(&task_name)
        .ok_or_else(|| anyhow::anyhow!("unknown task '{task_name}'"))?;

    // pretrained backbone + calibration on the *pretraining* corpus
    // (the paper's §5 choice-of-calibration-set finding)
    let corpus = Corpus::generate(spec.vocab, 200_000, 42);
    let pcfg = PretrainConfig { steps: 1500, lr: 2e-3, warmup: 30, seed: 42, log_every: 300 };
    let (ckpt, _) = pretrain(&reg, &spec, &corpus, &pcfg)?;
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 12, false)?;

    let train_set = task.generate(task.train_size(), spec.vocab, spec.seq, 10);
    let test_set = task.generate(256, spec.vocab, spec.seq, 11);
    println!(
        "task '{}' ({} classes, {} train examples), 2.50 W-bits, rank 8",
        task.name(),
        task.n_classes(),
        train_set.len()
    );

    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    let rank = 8;
    let mut table = Table::new(
        &format!("QPEFT {} on '{}' ({epochs} epochs x 3 seeds)", spec.name, task.name()),
        &["init method", "acc(seed42)", "acc(seed1)", "acc(seed2)", "mean"],
    );

    for method in [Method::QloraZero, Method::Loftq { iters: 5 }, Method::QeraApprox] {
        let mut accs = Vec::new();
        for seed in [42u64, 1, 2] {
            let init = lora_init(&ckpt, method, fmt, rank, Some(&calib), seed)?;
            let mut tr = LoraClsTrainer::new(spec.clone(), init, 3e-3, &mut Rng::new(seed));
            let mut rng = Rng::new(seed ^ 0xF1);
            for _ in 0..epochs {
                tr.train_epoch(&reg, &train_set, &mut rng)?;
            }
            accs.push(tr.accuracy(&reg, &test_set)?);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        table.row(vec![
            method.name(),
            format!("{:.3}", accs[0]),
            format!("{:.3}", accs[1]),
            format!("{:.3}", accs[2]),
            format!("{mean:.3}"),
        ]);
    }
    table.emit(&format!("qpeft_{}_{}", spec.name, task.name()));
    println!("Expected: qera-approx >= loftq:5 >= qlora at aggressive bits.");
    Ok(())
}
