//! Serving demo: a quantized model behind the dynamic batcher.
//!
//! Quantizes the subject model with QERA-approx, starts the server thread,
//! fires concurrent client bursts, and reports latency / throughput /
//! batching efficiency — the "no inference overhead" deployment story.
//!
//! ```bash
//! cargo run --release --example serve
//! ```

use qera::coordinator::{calibrate, quantize, PipelineConfig};
use qera::data::{Corpus, Tokenizer};
use qera::quant::QFormat;
use qera::runtime::Registry;
use qera::serve::{Server, ServerConfig};
use qera::solver::Method;
use qera::train::{pretrain, PretrainConfig};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    let spec = reg.spec("nano")?.clone();
    let tok = Tokenizer::new(spec.vocab);

    // pretrain + quantize (QERA-approx, 4.25 bits, rank 8)
    let corpus = Corpus::generate(spec.vocab, 150_000, 42);
    let pcfg = PretrainConfig { steps: 800, lr: 2e-3, warmup: 20, seed: 42, log_every: 200 };
    let (ckpt, _) = pretrain(&reg, &spec, &corpus, &pcfg)?;
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 8, false)?;
    let fmt = QFormat::Mxint { bits: 4, block: 32 };
    let qm = quantize(&ckpt, &PipelineConfig::new(Method::QeraApprox, fmt, 8), Some(&calib))?;
    println!(
        "serving {} quantized to {:.2} effective bits ({:.2} MB payload)",
        spec.name,
        qm.effective_bits(),
        qm.ckpt.payload_bytes() as f64 / 1e6
    );

    let server = Server::start(
        reg.dir.clone(),
        spec.clone(),
        qm.merged.clone(),
        ServerConfig { max_wait: Duration::from_millis(10), seed: 7 },
    );

    // three client bursts
    let t0 = Instant::now();
    let mut latencies = Vec::new();
    for burst in 0..3 {
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                let prompt = vec![(burst * 6 + i + 1) as i32 % spec.vocab as i32, 5, 9];
                server.submit(prompt, 16, 0.0)
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(300))?;
            latencies.push(resp.total_ms);
            if i == 0 {
                println!(
                    "burst {burst}: \"{}\" (batch={}, queue {:.1} ms, total {:.1} ms)",
                    tok.decode(&resp.tokens[..resp.tokens.len().min(8)]),
                    resp.batch_size,
                    resp.queue_ms,
                    resp.total_ms
                );
            }
        }
    }
    let stats = server.stop();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\n{} requests in {:.2}s | {:.1} tok/s | mean batch {:.2} | p50 {:.0} ms, p95 {:.0} ms",
        stats.requests,
        t0.elapsed().as_secs_f64(),
        stats.throughput_tok_s(),
        stats.mean_batch(),
        latencies[latencies.len() / 2],
        latencies[(latencies.len() - 1) * 95 / 100],
    );
    Ok(())
}
