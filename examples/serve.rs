//! Serving demo: a quantized model behind the supervised serving daemon.
//!
//! Quantizes the subject model with QERA-approx, starts the daemon, fires
//! concurrent client bursts, hot-swaps to a second checkpoint mid-traffic,
//! and reports latency / throughput / batching efficiency — the "no
//! inference overhead" deployment story.
//!
//! ```bash
//! cargo run --release --example serve
//! ```

use qera::coordinator::{calibrate, quantize, PipelineConfig};
use qera::data::{Corpus, Tokenizer};
use qera::quant::QFormat;
use qera::runtime::Registry;
use qera::serve::{ServeModel, Server, ServerConfig};
use qera::solver::Method;
use qera::train::{pretrain, PretrainConfig};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    let spec = reg.spec("nano")?.clone();
    let tok = Tokenizer::new(spec.vocab);

    // pretrain + quantize (QERA-approx, 4.25 bits, rank 8)
    let corpus = Corpus::generate(spec.vocab, 150_000, 42);
    let pcfg = PretrainConfig { steps: 800, lr: 2e-3, warmup: 20, seed: 42, log_every: 200 };
    let (ckpt, _) = pretrain(&reg, &spec, &corpus, &pcfg)?;
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 8, false)?;
    let fmt = QFormat::Mxint { bits: 4, block: 32 };
    let qm = quantize(&ckpt, &PipelineConfig::new(Method::QeraApprox, fmt, 8), Some(&calib))?;
    println!(
        "serving {} quantized to {:.2} effective bits ({:.2} MB payload)",
        spec.name,
        qm.effective_bits(),
        qm.ckpt.payload_bytes() as f64 / 1e6
    );

    let server = Server::start(
        reg.dir.clone(),
        spec.clone(),
        qm.merged.clone(),
        ServerConfig {
            max_wait: Duration::from_millis(10),
            seed: 7,
            deadline: Some(Duration::from_secs(300)),
            ..Default::default()
        },
    );

    // three client bursts; hot-swap to a higher-rank checkpoint after the
    // first — in-flight requests finish on the old model, later bursts
    // decode on the new one (watch model_version flip)
    let t0 = Instant::now();
    let mut latencies = Vec::new();
    for burst in 0..3 {
        if burst == 1 {
            let qm2 =
                quantize(&ckpt, &PipelineConfig::new(Method::QeraApprox, fmt, 16), Some(&calib))?;
            server.swap_model(spec.clone(), ServeModel::Dense(qm2.merged.clone()))?;
            println!("hot-swapped to rank-16 checkpoint");
        }
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let prompt = vec![((burst * 6 + i + 1) % spec.vocab) as i32, 5, 9];
                server.submit(prompt, 16, 0.0)
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h
                .map_err(|e| anyhow::anyhow!("admission rejected: {e}"))?
                .wait()
                .response()?;
            latencies.push(resp.total_ms);
            if i == 0 {
                println!(
                    "burst {burst}: \"{}\" (batch={}, model v{}, queue {:.1} ms, total {:.1} ms)",
                    tok.decode(&resp.tokens[..resp.tokens.len().min(8)]),
                    resp.batch_size,
                    resp.model_version,
                    resp.queue_ms,
                    resp.total_ms
                );
            }
        }
    }
    let stats = server.stop()?;
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "\n{}/{} requests in {:.2}s | {:.1} tok/s | mean batch {:.2} | {} swap(s) | p50 {:.0} ms, p95 {:.0} ms",
        stats.requests,
        stats.admitted,
        t0.elapsed().as_secs_f64(),
        stats.throughput_tok_s(),
        stats.mean_batch(),
        stats.swaps,
        latencies[latencies.len() / 2],
        latencies[(latencies.len() - 1) * 95 / 100],
    );
    Ok(())
}
