//! Bench-regression gate: diff a fresh `BENCH_solver.json` against the
//! committed `BENCH_baseline.json` and fail on median or tail regressions.
//!
//! ```bash
//! QERA_BENCH_SMOKE=1 cargo bench --bench hotpath -- psd tensor_matmul svd matmul solver calib qdq budget exec serve ckpt obs
//! cargo run --release --bin check_bench -- BENCH_solver.json BENCH_baseline.json
//! cargo run --release --bin check_bench -- BENCH_solver.json BENCH_baseline.json 0.25
//! ```
//!
//! For every bench group present in both files the gate compares, per
//! metric, the median over rows and fails (exit 1) when fresh exceeds the
//! baseline by more than the threshold (default +25%).  Gated metrics:
//!
//! * the group's LAST `p50` column — the optimized/shipped path (every
//!   hotpath table orders baseline columns first);
//! * EVERY `p95` column — the serving SLO tails (`serve` reports queue and
//!   total p95 separately; a daemon change that leaves medians flat but
//!   fattens the tails fails here);
//! * every `resume scan` column — the crash-recovery journal scan in the
//!   `ckpt` group sits before the shipped load path, so the last-p50 rule
//!   alone would not watch it.
//!
//! Metrics are matched between fresh and baseline by header name, so a
//! baseline that predates a new column simply does not gate it yet (the
//! refresh picks it up).  Groups absent from the baseline are reported but
//! do not fail, and a smoke-vs-full `_mode` mismatch skips the gate
//! entirely (the two profiles bench different shapes), so the gate
//! degrades gracefully while a baseline is being (re)established.  The
//! reverse direction is strict: a baseline group missing from the fresh
//! report counts as a failure (lost coverage, e.g. a narrowed bench
//! filter), so the gate cannot be silenced by dropping a group.
//!
//! Refreshing the baseline (run on the machine class CI uses, smoke mode):
//!
//! ```bash
//! QERA_BENCH_SMOKE=1 cargo bench --bench hotpath -- psd tensor_matmul svd matmul solver calib qdq budget exec serve ckpt obs
//! cp BENCH_solver.json BENCH_baseline.json   # then commit it
//! ```
//!
//! Gated groups: `svd`, `matmul`, `tensor_matmul`, `psd`, `solver`,
//! `calib` (blocked threaded rxx fold), `qdq` (threaded quantizer
//! kernels), `budget` (the mixed-precision planner's layer x cell
//! profiling pass), `exec` (the fused-from-packed matmul behind the
//! native serve/eval backend), `serve` (the supervised daemon end to end —
//! p50 AND p95 queue/total tails), `ckpt` (sharded-manifest checkpoint
//! I/O — the sha256-verified parallel reload AND the crash-recovery
//! resume-journal scan are the gated columns), `obs` (the observability
//! layer's disabled-path overhead — a span call site with tracing off must
//! stay one relaxed atomic load, so its `ns/op p50` column is gated).

use qera::util::json::Json;

/// One gated metric of a bench group: the column's header name and the
/// median of its numeric cells over the group's rows.
struct Metric {
    label: String,
    median: f64,
}

/// Median of the numeric cells in column `col` over a table's rows.
fn col_median(table: &Json, col: usize) -> Option<f64> {
    let mut vals: Vec<f64> = Vec::new();
    for row in table.get("rows")?.as_arr()? {
        let cells = row.as_arr()?;
        if let Some(v) = cells.get(col).and_then(Json::as_str) {
            if let Ok(x) = v.parse::<f64>() {
                if x.is_finite() && x > 0.0 {
                    vals.push(x);
                }
            }
        }
    }
    if vals.is_empty() {
        return None;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(vals[vals.len() / 2])
}

/// The gated metrics of a bench table:
///
/// * the LAST `p50` column — every hotpath table orders its `p50` columns
///   baseline-first (naive / exact / thin / serial) and optimized-path
///   last, so the gate watches the shipped kernel; pooling in the baseline
///   columns would let a regression hide behind the (slower, stable)
///   reference;
/// * every `p95` column — tail-latency SLOs (the `serve` group);
/// * every `resume scan` column — the `ckpt` group's crash-recovery scan,
///   a non-last p50 the rules above would otherwise miss.
fn group_metrics(table: &Json) -> Vec<Metric> {
    let Some(headers) = table.get("headers").and_then(Json::as_arr) else {
        return Vec::new();
    };
    let mut cols: Vec<usize> = Vec::new();
    if let Some(p50) = headers
        .iter()
        .enumerate()
        .filter(|(_, h)| h.as_str().map(|s| s.contains("p50")).unwrap_or(false))
        .map(|(i, _)| i)
        .next_back()
    {
        cols.push(p50);
    }
    for (i, h) in headers.iter().enumerate() {
        let gated = h
            .as_str()
            .map(|s| s.contains("p95") || s.contains("resume scan"))
            .unwrap_or(false);
        if gated {
            cols.push(i);
        }
    }
    cols.sort_unstable();
    cols.dedup();
    cols.into_iter()
        .filter_map(|c| {
            let label = headers[c].as_str()?.to_string();
            Some(Metric { label, median: col_median(table, c)? })
        })
        .collect()
}

/// Bench profile recorded by the hotpath bench (`_mode` table): smoke and
/// full mode run different shape sets, so their medians are not comparable.
fn report_mode(j: &Json) -> Option<&str> {
    j.get("_mode")?.get("rows")?.as_arr()?.first()?.as_arr()?.first()?.as_str()
}

/// Outcome of gating a fresh report against a baseline.
struct Gate {
    /// Human-readable verdict lines, one per metric/group event.
    lines: Vec<String>,
    /// Metrics compared against a baseline value.
    compared: usize,
    /// Regressions + lost-coverage failures.
    failures: usize,
    /// Smoke-vs-full mismatch: nothing comparable, gate skipped.
    mode_mismatch: bool,
}

/// The pure gate (unit-tested with doctored reports): compare every gated
/// metric of every shared group, flag >threshold regressions and baseline
/// groups missing from the fresh report.
fn gate(fresh: &Json, base: &Json, max_regress: f64) -> Option<Gate> {
    let (fresh_obj, base_obj) = (fresh.as_obj()?, base.as_obj()?);
    let mut g = Gate { lines: Vec::new(), compared: 0, failures: 0, mode_mismatch: false };

    if let (Some(f), Some(b)) = (report_mode(fresh), report_mode(base)) {
        if f != b {
            g.lines.push(format!(
                "bench-mode mismatch (fresh={f}, baseline={b}) — medians are not \
                 comparable; refresh the baseline in the same mode. Gate skipped."
            ));
            g.mode_mismatch = true;
            return Some(g);
        }
    }

    for (group, table) in fresh_obj {
        if group.starts_with('_') {
            continue; // metadata keys (the `_mode` table)
        }
        let f_metrics = group_metrics(table);
        if f_metrics.is_empty() {
            g.lines.push(format!("  {group:<14} no p50/p95 data in fresh report — skipped"));
            continue;
        }
        match base_obj.get(group) {
            Some(b_table) => {
                let b_metrics = group_metrics(b_table);
                for fm in &f_metrics {
                    // matched by header name: a brand-new column gates only
                    // after the next baseline refresh
                    let Some(bm) = b_metrics.iter().find(|m| m.label == fm.label) else {
                        g.lines.push(format!(
                            "  {group:<14} [{}] fresh {:.3} — column not in baseline \
                             (refresh to start gating)",
                            fm.label, fm.median
                        ));
                        continue;
                    };
                    g.compared += 1;
                    let ratio = fm.median / bm.median.max(f64::MIN_POSITIVE);
                    let verdict = if ratio > 1.0 + max_regress {
                        g.failures += 1;
                        "REGRESSION"
                    } else {
                        "ok"
                    };
                    g.lines.push(format!(
                        "  {group:<14} [{}] baseline {:.3} -> fresh {:.3} ({:+.1}%)  {verdict}",
                        fm.label,
                        bm.median,
                        fm.median,
                        (ratio - 1.0) * 100.0
                    ));
                }
            }
            None => {
                g.lines.push(format!(
                    "  {group:<14} fresh {:.3} — no committed baseline (refresh to start \
                     gating)",
                    f_metrics[0].median
                ));
            }
        }
    }
    // a baseline group absent from the fresh report means lost coverage
    // (renamed group, narrowed ci.yml bench filter, group crashed before
    // emitting) — fail loudly instead of gating on the survivors only
    for (group, table) in base_obj {
        if group.starts_with('_') || group_metrics(table).is_empty() {
            continue;
        }
        if !fresh_obj.contains_key(group) {
            g.failures += 1;
            g.lines.push(format!(
                "  {group:<14} in baseline but missing from fresh report (bench filter \
                 changed?)  REGRESSION"
            ));
        }
    }
    Some(g)
}

fn load(path: &str) -> Option<Json> {
    let text = std::fs::read_to_string(path).ok()?;
    Json::parse(&text).ok()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 {
        eprintln!("usage: check_bench <fresh.json> <baseline.json> [max_regress=0.25]");
        std::process::exit(2);
    }
    let max_regress: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.25);

    let Some(fresh) = load(&args[0]) else {
        eprintln!("check_bench: cannot read fresh report '{}'", args[0]);
        std::process::exit(2);
    };
    let Some(base) = load(&args[1]) else {
        println!(
            "check_bench: no readable baseline at '{}' — gate passes vacuously.",
            args[1]
        );
        println!(
            "refresh: QERA_BENCH_SMOKE=1 cargo bench --bench hotpath -- psd tensor_matmul \
             svd matmul solver calib qdq budget exec serve ckpt obs && cp {} {}",
            args[0], args[1]
        );
        return;
    };
    let Some(g) = gate(&fresh, &base, max_regress) else {
        eprintln!("check_bench: reports must be JSON objects of bench tables");
        std::process::exit(2);
    };
    for line in &g.lines {
        println!("{line}");
    }
    if g.mode_mismatch {
        return;
    }
    if g.failures > 0 {
        eprintln!(
            "check_bench: {} metric(s) regressed more than {:.0}% over the baseline (or \
             lost coverage)",
            g.failures,
            max_regress * 100.0
        );
        std::process::exit(1);
    }
    println!(
        "check_bench: {} metric(s) within +{:.0}% of baseline",
        g.compared,
        max_regress * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A one-group report in the `emit_json_report` shape, with a `serve`
    /// table carrying distinct p50 and p95 columns.
    fn serve_report(q50: &str, q95: &str, t50: &str, t95: &str) -> Json {
        Json::parse(&format!(
            r#"{{"serve": {{"headers": ["max-wait ms", "tok/s", "queue p50 ms",
                "queue p95 ms", "total p50 ms", "total p95 ms"],
               "rows": [["0", "900.0", "{q50}", "{q95}", "{t50}", "{t95}"]]}},
               "_mode": {{"headers": ["mode"], "rows": [["smoke"]]}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn p95_tail_regression_fails_even_with_flat_medians() {
        let base = serve_report("1.0", "2.0", "3.0", "4.0");
        // medians identical, total p95 fattened 2x — the SLO gate must fire
        let fresh = serve_report("1.0", "2.0", "3.0", "8.0");
        let g = gate(&fresh, &base, 0.25).unwrap();
        assert_eq!(g.failures, 1, "{:?}", g.lines);
        // last-p50 ("total p50 ms") + both p95 columns are gated
        assert_eq!(g.compared, 3);
        assert!(g.lines.iter().any(|l| l.contains("[total p95 ms]") && l.contains("REGRESSION")));
        // within-threshold tails pass
        let ok = serve_report("1.2", "2.4", "3.5", "4.9");
        let g2 = gate(&ok, &base, 0.25).unwrap();
        assert_eq!(g2.failures, 0, "{:?}", g2.lines);
        assert_eq!(g2.compared, 3);
    }

    #[test]
    fn last_p50_regression_fails_and_queue_p50_is_not_gated() {
        let base = serve_report("1.0", "2.0", "3.0", "4.0");
        // queue p50 (not the last p50 column) regresses 10x: not gated
        let queue_only = serve_report("10.0", "2.0", "3.0", "4.0");
        let g = gate(&queue_only, &base, 0.25).unwrap();
        assert_eq!(g.failures, 0, "{:?}", g.lines);
        // total p50 (the last p50 column) regresses: gated
        let total = serve_report("1.0", "2.0", "30.0", "4.0");
        let g2 = gate(&total, &base, 0.25).unwrap();
        assert_eq!(g2.failures, 1, "{:?}", g2.lines);
        assert!(g2.lines.iter().any(|l| l.contains("[total p50 ms]") && l.contains("REGRESSION")));
    }

    #[test]
    fn missing_group_and_new_column_behavior() {
        let base = serve_report("1.0", "2.0", "3.0", "4.0");
        // fresh report lost the serve group entirely -> coverage failure
        let empty = Json::parse(
            r#"{"_mode": {"headers": ["mode"], "rows": [["smoke"]]}}"#,
        )
        .unwrap();
        let g = gate(&empty, &base, 0.25).unwrap();
        assert_eq!(g.failures, 1, "{:?}", g.lines);
        // a fresh column the baseline predates is reported, not gated
        let base_old = Json::parse(
            r#"{"serve": {"headers": ["total p50 ms"], "rows": [["3.0"]]},
                "_mode": {"headers": ["mode"], "rows": [["smoke"]]}}"#,
        )
        .unwrap();
        let fresh = serve_report("1.0", "2.0", "3.0", "400.0");
        let g2 = gate(&fresh, &base_old, 0.25).unwrap();
        assert_eq!(g2.failures, 0, "{:?}", g2.lines);
        assert_eq!(g2.compared, 1); // only total p50 matched by name
    }

    #[test]
    fn mode_mismatch_skips_gate() {
        let base = serve_report("1.0", "2.0", "3.0", "4.0");
        let fresh = Json::parse(
            r#"{"serve": {"headers": ["total p50 ms", "total p95 ms"],
                "rows": [["300.0", "400.0"]]},
                "_mode": {"headers": ["mode"], "rows": [["full"]]}}"#,
        )
        .unwrap();
        let g = gate(&fresh, &base, 0.25).unwrap();
        assert!(g.mode_mismatch);
        assert_eq!(g.failures, 0);
        assert_eq!(g.compared, 0);
    }

    #[test]
    fn resume_scan_column_is_gated_alongside_last_p50() {
        let ckpt_report = |scan: &str, load: &str| {
            Json::parse(&format!(
                r#"{{"ckpt": {{"headers": ["m", "shard write p50", "mono load p50",
                    "resume scan p50", "sharded verified load p50"],
                   "rows": [["256", "1.0", "2.0", "{scan}", "{load}"]]}},
                   "_mode": {{"headers": ["mode"], "rows": [["smoke"]]}}}}"#
            ))
            .unwrap()
        };
        let base = ckpt_report("3.0", "4.0");
        // a scan-only regression fires even though the last p50 is flat
        let slow_scan = ckpt_report("30.0", "4.0");
        let g = gate(&slow_scan, &base, 0.25).unwrap();
        assert_eq!(g.failures, 1, "{:?}", g.lines);
        assert_eq!(g.compared, 2, "resume scan + sharded load are gated");
        assert!(g
            .lines
            .iter()
            .any(|l| l.contains("[resume scan p50]") && l.contains("REGRESSION")));
        // the write/mono baseline columns stay ungated
        let g2 = gate(&ckpt_report("3.2", "4.3"), &base, 0.25).unwrap();
        assert_eq!(g2.failures, 0, "{:?}", g2.lines);
    }

    #[test]
    fn median_is_over_rows_and_ignores_non_numeric() {
        let t = Json::parse(
            r#"{"headers": ["name", "p50 ms"],
                "rows": [["a", "1.0"], ["b", "3.0"], ["c", "2.0"], ["d", "n/a"]]}"#,
        )
        .unwrap();
        let m = group_metrics(&t);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].label, "p50 ms");
        assert_eq!(m[0].median, 2.0);
    }
}
