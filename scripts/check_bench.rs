//! Bench-regression gate: diff a fresh `BENCH_solver.json` against the
//! committed `BENCH_baseline.json` and fail on a median regression.
//!
//! ```bash
//! QERA_BENCH_SMOKE=1 cargo bench --bench hotpath -- psd tensor_matmul svd matmul solver calib qdq budget exec
//! cargo run --release --bin check_bench -- BENCH_solver.json BENCH_baseline.json
//! cargo run --release --bin check_bench -- BENCH_solver.json BENCH_baseline.json 0.25
//! ```
//!
//! For every bench group present in both files, the gate takes the median
//! over rows of the group's LAST `p50` column — the optimized/shipped
//! path (every hotpath table orders baseline columns first) — and fails
//! (exit 1) when the fresh median exceeds the baseline by more than the
//! threshold (default +25%).  Groups absent from the baseline are
//! reported but do not fail, and a smoke-vs-full `_mode` mismatch skips
//! the gate entirely (the two profiles bench different shapes), so the
//! gate degrades gracefully while a baseline is being (re)established.
//! The reverse direction is strict: a baseline group missing from the
//! fresh report counts as a failure (lost coverage, e.g. a narrowed
//! bench filter), so the gate cannot be silenced by dropping a group.
//!
//! Refreshing the baseline (run on the machine class CI uses, smoke mode):
//!
//! ```bash
//! QERA_BENCH_SMOKE=1 cargo bench --bench hotpath -- psd tensor_matmul svd matmul solver calib qdq budget exec
//! cp BENCH_solver.json BENCH_baseline.json   # then commit it
//! ```
//!
//! Gated groups (each table's last `p50` column is the shipped path):
//! `svd`, `matmul`, `tensor_matmul`, `psd`, `solver`, `calib` (blocked
//! threaded rxx fold), `qdq` (threaded quantizer kernels), `budget` (the
//! mixed-precision planner's layer x cell profiling pass), `exec` (the
//! fused-from-packed matmul behind the native serve/eval backend).

use qera::util::json::Json;

/// Median over rows of a bench table's shipped-path timing column.
///
/// Every hotpath table orders its `p50` columns baseline-first (naive /
/// exact / thin / serial) and optimized-path last (auto / randomized /
/// lowrank / the single solver total), so the gate watches only the LAST
/// `p50` column — pooling in the baseline columns would let a regression
/// in the shipped kernel hide behind the (slower, stable) reference.
fn group_median(table: &Json) -> Option<f64> {
    let headers = table.get("headers")?.as_arr()?;
    let col = headers
        .iter()
        .enumerate()
        .filter(|(_, h)| h.as_str().map(|s| s.contains("p50")).unwrap_or(false))
        .map(|(i, _)| i)
        .next_back()?;
    let mut vals: Vec<f64> = Vec::new();
    for row in table.get("rows")?.as_arr()? {
        let cells = row.as_arr()?;
        if let Some(v) = cells.get(col).and_then(Json::as_str) {
            if let Ok(x) = v.parse::<f64>() {
                if x.is_finite() && x > 0.0 {
                    vals.push(x);
                }
            }
        }
    }
    if vals.is_empty() {
        return None;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(vals[vals.len() / 2])
}

/// Bench profile recorded by the hotpath bench (`_mode` table): smoke and
/// full mode run different shape sets, so their medians are not comparable.
fn report_mode(j: &Json) -> Option<&str> {
    j.get("_mode")?.get("rows")?.as_arr()?.first()?.as_arr()?.first()?.as_str()
}

fn load(path: &str) -> Option<Json> {
    let text = std::fs::read_to_string(path).ok()?;
    Json::parse(&text).ok()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 {
        eprintln!("usage: check_bench <fresh.json> <baseline.json> [max_regress=0.25]");
        std::process::exit(2);
    }
    let max_regress: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.25);

    let Some(fresh) = load(&args[0]) else {
        eprintln!("check_bench: cannot read fresh report '{}'", args[0]);
        std::process::exit(2);
    };
    let Some(base) = load(&args[1]) else {
        println!(
            "check_bench: no readable baseline at '{}' — gate passes vacuously.",
            args[1]
        );
        println!(
            "refresh: QERA_BENCH_SMOKE=1 cargo bench --bench hotpath -- psd tensor_matmul \
             svd matmul solver calib qdq budget exec && cp {} {}",
            args[0], args[1]
        );
        return;
    };
    let (Some(fresh_obj), Some(base_obj)) = (fresh.as_obj(), base.as_obj()) else {
        eprintln!("check_bench: reports must be JSON objects of bench tables");
        std::process::exit(2);
    };

    if let (Some(f), Some(b)) = (report_mode(&fresh), report_mode(&base)) {
        if f != b {
            println!(
                "check_bench: bench-mode mismatch (fresh={f}, baseline={b}) — medians are \
                 not comparable; refresh the baseline in the same mode. Gate skipped."
            );
            return;
        }
    }

    let mut failures = 0usize;
    let mut compared = 0usize;
    for (group, table) in fresh_obj {
        if group.starts_with('_') {
            continue; // metadata keys in hand-edited baselines
        }
        let Some(f_med) = group_median(table) else {
            println!("  {group:<14} no p50 data in fresh report — skipped");
            continue;
        };
        match base_obj.get(group).and_then(group_median) {
            Some(b_med) => {
                compared += 1;
                let ratio = f_med / b_med.max(f64::MIN_POSITIVE);
                let verdict = if ratio > 1.0 + max_regress {
                    failures += 1;
                    "REGRESSION"
                } else {
                    "ok"
                };
                println!(
                    "  {group:<14} baseline {b_med:.3} ms -> fresh {f_med:.3} ms \
                     ({:+.1}%)  {verdict}",
                    (ratio - 1.0) * 100.0
                );
            }
            None => {
                println!(
                    "  {group:<14} fresh {f_med:.3} ms — no committed baseline \
                     (refresh to start gating)"
                );
            }
        }
    }
    // a baseline group absent from the fresh report means lost coverage
    // (renamed group, narrowed ci.yml bench filter, group crashed before
    // emitting) — fail loudly instead of gating on the survivors only
    for (group, table) in base_obj {
        if group.starts_with('_') || group_median(table).is_none() {
            continue;
        }
        if !fresh_obj.contains_key(group) {
            failures += 1;
            println!(
                "  {group:<14} in baseline but missing from fresh report \
                 (bench filter changed?)  REGRESSION"
            );
        }
    }
    if failures > 0 {
        eprintln!(
            "check_bench: {failures} group(s) regressed more than {:.0}% over the baseline \
             (or lost coverage)",
            max_regress * 100.0
        );
        std::process::exit(1);
    }
    println!("check_bench: {compared} group(s) within +{:.0}% of baseline", max_regress * 100.0);
}
