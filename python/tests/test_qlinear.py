"""Fused low-rank qlinear Pallas kernel vs oracle + hypothesis shape sweep."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qlinear, ref


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _case(m, k, n, r, seed=0):
    return (
        _rand((m, k), seed),
        _rand((k, n), seed + 1),
        _rand((k, r), seed + 2),
        _rand((r, n), seed + 3),
    )


@pytest.mark.parametrize("m,k,n,r", [(8, 16, 8, 2), (32, 64, 48, 8), (16, 128, 96, 16)])
def test_matches_ref(m, k, n, r):
    x, w, a, b = _case(m, k, n, r)
    got = qlinear.qlinear_lowrank(x, w, a, b)
    want = ref.qlinear_lowrank(x, w, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn", [(4, 8), (8, 16), (16, 48), (32, 24)])
def test_tiling_invariant(bm, bn):
    """Output must be identical (to fp tolerance) for any legal tiling."""
    x, w, a, b = _case(32, 64, 48, 8, seed=42)
    full = qlinear.qlinear_lowrank(x, w, a, b)
    tiled = qlinear.qlinear_lowrank(x, w, a, b, bm=bm, bn=bn)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), rtol=1e-5, atol=1e-5)


def test_zero_lowrank_is_plain_matmul():
    x, w, a, b = _case(8, 32, 16, 4, seed=1)
    a = jnp.zeros_like(a)
    got = qlinear.qlinear_lowrank(x, w, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-5, atol=1e-6)


def test_reconstruction_identity():
    """With w~ = w - AB the kernel reconstructs x@w exactly (rank-full case):
    the algebra behind the whole QER formulation."""
    x, w, a, b = _case(8, 32, 16, 4, seed=2)
    wt = w - a @ b
    got = qlinear.qlinear_lowrank(x, wt, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    m=st.sampled_from([1, 2, 4, 8, 16]),
    k=st.sampled_from([8, 16, 32, 64]),
    n=st.sampled_from([8, 16, 32]),
    r=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(m, k, n, r, seed):
    x, w, a, b = _case(m, k, n, r, seed=seed % 10_000)
    got = qlinear.qlinear_lowrank(x, w, a, b)
    want = ref.qlinear_lowrank(x, w, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
