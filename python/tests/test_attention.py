"""Causal-attention Pallas kernel vs oracle: masking, blocking, stability."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref


def _qkv(t, s, hd, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray((rng.normal(size=(t, s, hd)) * scale).astype(np.float32))
    return mk(), mk(), mk()


@pytest.mark.parametrize("t,s,hd", [(2, 8, 4), (6, 16, 8), (8, 64, 16)])
def test_matches_ref(t, s, hd):
    q, k, v = _qkv(t, s, hd, seed=t * 100 + s)
    scale = 1.0 / hd ** 0.5
    got = attention.causal_attention(q, k, v, scale)
    want = ref.causal_attention(q, k, v, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bq", [2, 4, 8, 16])
def test_query_blocking_invariant(bq):
    q, k, v = _qkv(4, 16, 8, seed=3)
    scale = 0.35
    full = attention.causal_attention(q, k, v, scale)
    tiled = attention.causal_attention(q, k, v, scale, bq=bq)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), rtol=1e-5, atol=1e-6)


def test_causality():
    """Changing future keys/values must not change earlier outputs."""
    q, k, v = _qkv(2, 16, 8, seed=9)
    scale = 0.3
    base = np.asarray(attention.causal_attention(q, k, v, scale))
    k2 = k.at[:, 8:, :].set(123.0)
    v2 = v.at[:, 8:, :].set(-55.0)
    pert = np.asarray(attention.causal_attention(q, k2, v2, scale))
    np.testing.assert_allclose(base[:, :8, :], pert[:, :8, :], rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[:, 8:, :], pert[:, 8:, :])


def test_first_position_copies_v0():
    """Row 0 attends only to position 0 -> output == v[:,0,:]."""
    q, k, v = _qkv(3, 8, 4, seed=5)
    out = np.asarray(attention.causal_attention(q, k, v, 0.5))
    np.testing.assert_allclose(out[:, 0, :], np.asarray(v[:, 0, :]), rtol=1e-6, atol=1e-6)


def test_large_logit_stability():
    q, k, v = _qkv(2, 16, 8, seed=7, scale=40.0)
    out = np.asarray(attention.causal_attention(q, k, v, 1.0))
    assert np.isfinite(out).all()


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 8, 16, 32]),
    hd=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_kernel_vs_ref(t, s, hd, seed):
    q, k, v = _qkv(t, s, hd, seed=seed % 100_000)
    scale = 1.0 / hd ** 0.5
    got = attention.causal_attention(q, k, v, scale)
    want = ref.causal_attention(q, k, v, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
