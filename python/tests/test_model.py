"""L2 model tests: shapes, pallas-vs-jnp path equality, LoRA algebra, grads."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import CONFIGS

CFG = CONFIGS["micro"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq)), jnp.int32)


def test_param_layout_count(params):
    assert len(params) == len(CFG.param_layout())
    for p, (name, shape) in zip(params, CFG.param_layout()):
        assert p.shape == shape, name


def test_logits_shape(params, tokens):
    (logits,) = model.lm_fwd(CFG, tokens, *params)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pallas_path_matches_jnp_path(params, tokens):
    """The lowered (pallas) forward must equal the oracle forward."""
    (logits,) = model.lm_fwd(CFG, tokens, *params)
    want = model.ref_lm_fwd(CFG, params, tokens)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_causality(params, tokens):
    """Perturbing token t must not change logits before t."""
    (base,) = model.lm_fwd(CFG, tokens, *params)
    t2 = tokens.at[:, CFG.seq // 2].set((tokens[:, CFG.seq // 2] + 1) % CFG.vocab)
    (pert,) = model.lm_fwd(CFG, t2, *params)
    cut = CFG.seq // 2
    np.testing.assert_allclose(
        np.asarray(base)[:, :cut], np.asarray(pert)[:, :cut], rtol=1e-5, atol=1e-5
    )


def test_nll_consistent_with_logits(params, tokens):
    targets = jnp.roll(tokens, -1, axis=1)
    (nll,) = model.lm_nll(CFG, tokens, targets, *params)
    (logits,) = model.lm_fwd(CFG, tokens, *params)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    np.testing.assert_allclose(np.asarray(nll), np.asarray(lse - gold), rtol=1e-4, atol=1e-4)
    assert float(jnp.mean(nll)) > 0


def test_logits_last_matches_fwd(params, tokens):
    (last,) = model.lm_logits_last(CFG, tokens, *params)
    (full,) = model.lm_fwd(CFG, tokens, *params)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full)[:, -1], rtol=1e-5, atol=1e-5)


def test_taps_shapes(params, tokens):
    out = model.lm_fwd_taps(CFG, tokens, *params)
    taps = out[1:]
    layout = CFG.tap_layout()
    assert len(taps) == len(layout)
    for t, (name, shape) in zip(taps, layout):
        assert t.shape == shape, name


def test_zero_lora_is_identity(params, tokens):
    rank = 2
    lora = model.zero_lora(CFG, rank)
    (base,) = model.lm_fwd(CFG, tokens, *params)
    logits, _ = model.lm_logits(CFG, params, tokens, lora=lora, rank=rank, use_pallas=False)
    np.testing.assert_allclose(np.asarray(base), np.asarray(logits), rtol=2e-4, atol=2e-4)


def test_lora_merge_equivalence(params, tokens):
    """fwd(base, lora) == fwd(base with W += A@B): the merged-weight identity
    the Rust evaluator uses everywhere."""
    rank = 2
    key = jax.random.PRNGKey(1)
    lora = []
    for _, shape in CFG.lora_layout(rank):
        key, sub = jax.random.split(key)
        lora.append(0.05 * jax.random.normal(sub, shape, jnp.float32))
    logits_lr, _ = model.lm_logits(CFG, params, tokens, lora=lora, rank=rank, use_pallas=False)

    merged = list(params)
    names = [n for n, _ in CFG.param_layout()]
    li = 0
    for i in range(CFG.n_layers):
        for site in ("wq", "wk", "wv", "wo", "w_up", "w_down"):
            a, b = lora[li], lora[li + 1]
            li += 2
            idx = names.index(f"blk{i}.{site}")
            merged[idx] = merged[idx] + a @ b
    merged_logits, _ = model.lm_logits(CFG, merged, tokens, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(logits_lr), np.asarray(merged_logits), rtol=2e-3, atol=2e-3
    )


def test_lora_lm_step_grads(params, tokens):
    rank = 2
    targets = jnp.roll(tokens, -1, axis=1)
    # LoRA init: A Gaussian, B zero.  Then dL/dA = (x^T dY) B^T = 0 while
    # dL/dB = (xA)^T dY is generically nonzero.
    key = jax.random.PRNGKey(7)
    lora = []
    for name, shape in CFG.lora_layout(rank):
        if name.endswith(".A"):
            key, sub = jax.random.split(key)
            lora.append(0.1 * jax.random.normal(sub, shape, jnp.float32))
        else:
            lora.append(jnp.zeros(shape, jnp.float32))
    out = model.lora_lm_step(CFG, rank, tokens, targets, *params, *lora)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert len(grads) == len(lora)
    nz = 0
    for i, g in enumerate(grads):
        if i % 2 == 0:
            assert float(jnp.max(jnp.abs(g))) < 1e-6, f"dA[{i}] should vanish when B=0"
        else:
            nz += float(jnp.max(jnp.abs(g))) > 0
    assert nz > 0


def test_pretrain_step_decreases_loss(params, tokens):
    targets = jnp.roll(tokens, -1, axis=1)
    out = model.pretrain_step(CFG, tokens, targets, *params)
    loss0, grads = out[0], out[1:]
    stepped = [p - 0.5 * g for p, g in zip(params, grads)]
    out2 = model.pretrain_step(CFG, tokens, targets, *stepped)
    assert float(out2[0]) < float(loss0)


def test_cls_step_and_fwd(params, tokens):
    rank = 2
    rng = np.random.default_rng(2)
    labels = jnp.asarray(rng.integers(0, CFG.n_classes, size=(CFG.batch,)), jnp.int32)
    lora = model.zero_lora(CFG, rank)
    hw = jnp.asarray(0.02 * rng.normal(size=(CFG.d_model, CFG.n_classes)), jnp.float32)
    hb = jnp.zeros((CFG.n_classes,), jnp.float32)
    out = model.lora_cls_step(CFG, rank, tokens, labels, *params, *lora, hw, hb)
    loss, g_hw, g_hb = out[0], out[-2], out[-1]
    assert np.isfinite(float(loss))
    assert g_hw.shape == hw.shape and g_hb.shape == hb.shape
    assert float(jnp.max(jnp.abs(g_hw))) > 0
    (cls,) = model.cls_fwd(CFG, rank, tokens, *params, *lora, hw, hb)
    assert cls.shape == (CFG.batch, CFG.n_classes)


def test_full_cls_step(params, tokens):
    rng = np.random.default_rng(3)
    labels = jnp.asarray(rng.integers(0, CFG.n_classes, size=(CFG.batch,)), jnp.int32)
    hw = jnp.asarray(0.02 * rng.normal(size=(CFG.d_model, CFG.n_classes)), jnp.float32)
    hb = jnp.zeros((CFG.n_classes,), jnp.float32)
    out = model.full_cls_step(CFG, tokens, labels, *params, hw, hb)
    loss, grads = out[0], out[1:]
    assert len(grads) == len(params) + 2
    assert np.isfinite(float(loss))
