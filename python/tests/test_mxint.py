"""MXINT Pallas kernel vs pure-jnp oracle, plus format invariants.

The quantizer is the paper's q(.)/dq(.); the Rust `quant::mxint` module
mirrors the same formula, so this file (together with the Rust round-trip
tests against these vectors) pins all three implementations together.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mxint, ref


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


@pytest.mark.parametrize("bits", [2, 3, 4, 6, 8])
@pytest.mark.parametrize("block_size", [16, 32])
def test_kernel_matches_ref_exactly(bits, block_size):
    x = _rand((16, 4 * block_size), seed=bits * 10 + block_size)
    got = mxint.mxint_qdq(x, bits, block_size)
    want = ref.mxint_qdq(x, bits, block_size)
    assert bool(jnp.all(got == want)), f"bits={bits} bs={block_size}"


@pytest.mark.parametrize("rows_per_step", [1, 2, 8])
def test_grid_partition_invariant(rows_per_step):
    """Tiling the grid must not change results (BlockSpec correctness)."""
    x = _rand((8, 64), seed=7)
    full = mxint.mxint_qdq(x, 4, 32)
    tiled = mxint.mxint_qdq(x, 4, 32, rows_per_step=rows_per_step)
    assert bool(jnp.all(full == tiled))


def test_zero_block_maps_to_zero():
    x = jnp.zeros((4, 32), jnp.float32)
    assert bool(jnp.all(mxint.mxint_qdq(x, 4, 32) == 0))


def test_idempotent():
    """q(dq(q(x))) == q(x): quantization is a projection."""
    x = _rand((8, 64), seed=3)
    once = ref.mxint_qdq(x, 4, 32)
    twice = ref.mxint_qdq(once, 4, 32)
    assert bool(jnp.all(once == twice))


def test_scale_equivariance_pow2():
    """MXINT is exactly equivariant to power-of-two scaling."""
    x = _rand((8, 64), seed=5)
    a = ref.mxint_qdq(x * 4.0, 4, 32)
    b = ref.mxint_qdq(x, 4, 32) * 4.0
    assert bool(jnp.all(a == b))


def test_negation_symmetry():
    x = _rand((8, 64), seed=11)
    a = ref.mxint_qdq(-x, 4, 32)
    b = -ref.mxint_qdq(x, 4, 32)
    assert bool(jnp.all(a == b))


def test_error_bound():
    """|x - dq(q(x))| <= scale/2 = 2^(e - bits + 1) per block (pre-clamp
    region), and relative block error is bounded by 2^-(bits-2)."""
    x = _rand((32, 64), seed=13, scale=3.0)
    for bits in (3, 4, 6):
        y = np.asarray(ref.mxint_qdq(x, bits, 32))
        g = np.asarray(x).reshape(-1, 32)
        gy = y.reshape(-1, 32)
        amax = np.abs(g).max(axis=1)
        err = np.abs(g - gy).max(axis=1)
        # max element error: half an lsb of the shared scale, except at the
        # symmetric clamp where it's at most 1 lsb.
        lsb = 2.0 ** (np.floor(np.log2(amax)) - (bits - 2))
        assert np.all(err <= lsb * 1.0 + 1e-9), bits


@settings(max_examples=40, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    bs=st.sampled_from([16, 32]),
    rows=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-4, 1e4),
)
def test_hypothesis_kernel_vs_ref(bits, bs, rows, seed, scale):
    x = _rand((rows, 2 * bs), seed=seed, scale=scale)
    got = mxint.mxint_qdq(x, bits, bs)
    want = ref.mxint_qdq(x, bits, bs)
    assert bool(jnp.all(got == want))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_bounded_and_finite(seed):
    x = _rand((4, 32), seed=seed, scale=10.0)
    y = ref.mxint_qdq(x, 4, 32)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dequantized magnitudes can exceed amax by at most the clamp bound
    amax = jnp.max(jnp.abs(x))
    assert float(jnp.max(jnp.abs(y))) <= float(amax) * 2.0 + 1e-6


def test_golden_vectors():
    """Golden values shared with the Rust test-suite (quant::mxint)."""
    x = jnp.asarray(
        [1.0, -1.0, 0.5, 0.25, 3.0, -2.5, 0.1, 0.0] * 4, jnp.float32
    ).reshape(1, 32)
    y = np.asarray(ref.mxint_qdq(x, 4, 32)).reshape(-1)
    # amax = 3.0 -> e = 1 -> scale = 2^(1-2) = 0.5
    # 0.25/0.5 = 0.5 rounds to 0 (ties-to-even); 0.1/0.5 = 0.2 rounds to 0.
    want = np.array([1.0, -1.0, 0.5, 0.0, 3.0, -2.5, 0.0, 0.0] * 4, np.float32)
    np.testing.assert_array_equal(y, want)
