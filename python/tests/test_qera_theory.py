"""Numpy oracle of the paper's theory (Theorems 1 & 2) — the reference the
Rust `solver` module is pinned against.

Checks, on random instances:
  * QERA-exact attains the minimum expected output error among all tested
    rank-k reconstructions (it is the closed-form argmin of Problem 2);
  * QERA-approx == QERA-exact when Assumption 1 holds exactly (diagonal R);
  * ZeroQuant-V2 (plain SVD_k) minimizes the *weight* error (Problem 1) but
    is beaten on *output* error by QERA when activations are anisotropic —
    the paper's central claim;
  * the CALDERA equivalence of Appendix A.3.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as kref
import jax.numpy as jnp


# --- solver oracles ---------------------------------------------------------


def svd_k(m, k):
    u, s, vt = np.linalg.svd(m, full_matrices=False)
    return u[:, :k] * s[:k], vt[:k]


def psd_sqrt(r, eps=1e-12):
    w, v = np.linalg.eigh((r + r.T) / 2)
    w = np.clip(w, eps * max(w.max(), 1e-30), None)
    return (v * np.sqrt(w)) @ v.T, (v / np.sqrt(w)) @ v.T


def solve_zeroquant(err, k):
    a, b = svd_k(err, k)
    return a @ b


def solve_qera_approx(err, sumsq_mean, k):
    s = np.sqrt(np.maximum(sumsq_mean, 1e-30))
    a, b = svd_k(s[:, None] * err, k)
    return (a / s[:, None]) @ b


def solve_qera_exact(err, rxx, k):
    rh, rhinv = psd_sqrt(rxx)
    a, b = svd_k(rh @ err, k)
    return (rhinv @ a) @ b


def out_err(x, p):
    """Mean squared output error E||xP||^2 over rows of x."""
    return float(np.mean(np.sum((x @ p) ** 2, axis=1)))


def make_instance(m=24, n=16, k=4, seed=0, aniso=True):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, n)).astype(np.float64)
    wq = np.asarray(kref.mxint_qdq(jnp.asarray(w.astype(np.float32)), 3, 8), np.float64)
    err = w - wq
    # anisotropic, correlated activations (what real LLM layers look like)
    nsamp = 512
    mix = rng.normal(size=(m, m)) / np.sqrt(m)
    if aniso:
        scales = np.exp(rng.normal(size=m) * 1.5)
        mix = mix * scales[None, :]
    x = rng.normal(size=(nsamp, m)) @ mix
    rxx = x.T @ x / nsamp
    sumsq = np.mean(x * x, axis=0)
    return w, wq, err, x, rxx, sumsq


def test_qera_exact_is_optimal():
    for seed in range(5):
        w, wq, err, x, rxx, sumsq = make_instance(seed=seed)
        k = 4
        cands = {
            "zq": solve_zeroquant(err, k),
            "approx": solve_qera_approx(err, sumsq, k),
            "exact": solve_qera_exact(err, rxx, k),
        }
        errs = {name: out_err(x, wq + c - w) for name, c in cands.items()}
        assert errs["exact"] <= errs["zq"] + 1e-9, (seed, errs)
        assert errs["exact"] <= errs["approx"] + 1e-9, (seed, errs)


def test_qera_beats_zeroquant_when_anisotropic():
    wins = 0
    for seed in range(8):
        w, wq, err, x, rxx, sumsq = make_instance(seed=seed, aniso=True)
        e_zq = out_err(x, wq + solve_zeroquant(err, 4) - w)
        e_qe = out_err(x, wq + solve_qera_exact(err, rxx, 4) - w)
        wins += e_qe < e_zq * 0.999
    assert wins >= 6, wins


def test_zeroquant_minimizes_weight_error():
    """Problem 1: plain SVD_k is the weight-error argmin (Eckart–Young)."""
    w, wq, err, x, rxx, sumsq = make_instance(seed=1)
    c_zq = solve_zeroquant(err, 4)
    for other in (solve_qera_exact(err, rxx, 4), solve_qera_approx(err, sumsq, 4)):
        assert np.linalg.norm(err - c_zq) <= np.linalg.norm(err - other) + 1e-9


def test_approx_equals_exact_under_assumption1():
    """If R_XX is exactly diagonal, Theorem 2 reduces to Theorem 1."""
    rng = np.random.default_rng(3)
    m, n, k = 12, 10, 3
    err = rng.normal(size=(m, n))
    d = np.exp(rng.normal(size=m))
    rxx = np.diag(d)
    c_ex = solve_qera_exact(err, rxx, k)
    c_ap = solve_qera_approx(err, d, k)
    np.testing.assert_allclose(c_ex, c_ap, rtol=1e-7, atol=1e-9)


def test_identity_rxx_reduces_to_zeroquant():
    rng = np.random.default_rng(4)
    err = rng.normal(size=(10, 8))
    c_ex = solve_qera_exact(err, np.eye(10), 3)
    c_zq = solve_zeroquant(err, 3)
    np.testing.assert_allclose(c_ex, c_zq, rtol=1e-8, atol=1e-10)


def test_rank_monotone_output_error():
    """QERA's output error decreases monotonically in k (Fig 1 claim)."""
    w, wq, err, x, rxx, _ = make_instance(seed=5)
    prev = None
    for k in (1, 2, 4, 8, 12):
        e = out_err(x, wq + solve_qera_exact(err, rxx, k) - w)
        if prev is not None:
            assert e <= prev + 1e-9, k
        prev = e


def test_full_rank_recovers_exactly():
    w, wq, err, x, rxx, _ = make_instance(seed=6)
    c = solve_qera_exact(err, rxx, min(err.shape))
    np.testing.assert_allclose(c, err, rtol=1e-6, atol=1e-8)


def test_caldera_equivalence():
    """Appendix A.3: QERA-exact == V Σ · SVD_k(U^T Y) / sqrt(b) form built
    from the SVD of the calibration matrix X."""
    rng = np.random.default_rng(7)
    b, m, n, k = 128, 12, 10, 3
    x = rng.normal(size=(b, m)) @ (rng.normal(size=(m, m)) / np.sqrt(m))
    w = rng.normal(size=(m, n))
    rxx = x.T @ x / b
    # QERA on the "approximate W itself" problem (W~ = 0)
    c_qera = solve_qera_exact(w, rxx, k)
    u, s, vt = np.linalg.svd(x, full_matrices=False)
    y = x @ w
    uk, bk = svd_k(u.T @ y, k)
    c_cald = (vt.T * (1.0 / s)) @ (uk @ bk)
    np.testing.assert_allclose(c_qera, c_cald, rtol=1e-6, atol=1e-8)


def test_expected_error_identity():
    """E||xP||^2 == Tr(R_XX P P^T): Equation (15), the pivot of the proof."""
    rng = np.random.default_rng(8)
    m, n, ns = 10, 6, 4096
    x = rng.normal(size=(ns, m)) @ (rng.normal(size=(m, m)) / np.sqrt(m))
    p = rng.normal(size=(m, n))
    lhs = np.mean(np.sum((x @ p) ** 2, axis=1))
    rxx = x.T @ x / ns
    rhs = np.trace(rxx @ p @ p.T)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-10)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 6))
def test_hypothesis_exact_beats_candidates(seed, k):
    w, wq, err, x, rxx, sumsq = make_instance(seed=seed % 100_000)
    e_exact = out_err(x, wq + solve_qera_exact(err, rxx, k) - w)
    e_zq = out_err(x, wq + solve_zeroquant(err, k) - w)
    e_ap = out_err(x, wq + solve_qera_approx(err, sumsq, k) - w)
    assert e_exact <= e_zq * (1 + 1e-7) + 1e-12
    assert e_exact <= e_ap * (1 + 1e-7) + 1e-12
