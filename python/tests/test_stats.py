"""Calibration-statistics Pallas kernel vs oracle; accumulation invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stats


def _x(r, m, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(r, m)).astype(np.float32))


@pytest.mark.parametrize("r,m", [(8, 16), (64, 32), (256, 128)])
def test_matches_ref(r, m):
    x = _x(r, m, seed=r + m)
    got = stats.calib_stats(x)
    want = ref.calib_stats(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("br", [1, 2, 8, 32])
def test_row_blocking_invariant(br):
    x = _x(64, 32, seed=1)
    full = stats.calib_stats(x)
    tiled = stats.calib_stats(x, br=br)
    for f, t in zip(full, tiled):
        np.testing.assert_allclose(np.asarray(f), np.asarray(t), rtol=1e-4, atol=1e-4)


def test_rxx_symmetric_psd():
    x = _x(128, 16, seed=2)
    _, _, rxx = stats.calib_stats(x)
    r = np.asarray(rxx, np.float64)
    np.testing.assert_allclose(r, r.T, rtol=1e-5, atol=1e-5)
    evals = np.linalg.eigvalsh((r + r.T) / 2)
    assert evals.min() >= -1e-3 * max(1.0, evals.max())


def test_diag_of_rxx_is_sumsq():
    x = _x(64, 24, seed=3)
    sumsq, _, rxx = stats.calib_stats(x)
    np.testing.assert_allclose(np.diag(np.asarray(rxx)), np.asarray(sumsq), rtol=1e-4, atol=1e-4)


def test_additivity_across_batches():
    """stats(concat(a,b)) == stats(a) + stats(b): the property the Rust
    coordinator's streaming accumulation relies on."""
    a, b = _x(32, 16, seed=4), _x(48, 16, seed=5)
    both = jnp.concatenate([a, b], axis=0)
    sa = [np.asarray(t, np.float64) for t in ref.calib_stats(a)]
    sb = [np.asarray(t, np.float64) for t in ref.calib_stats(b)]
    sc = [np.asarray(t, np.float64) for t in ref.calib_stats(both)]
    for x1, x2, x12 in zip(sa, sb, sc):
        np.testing.assert_allclose(x1 + x2, x12, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    r=st.sampled_from([2, 4, 16, 64]),
    m=st.sampled_from([4, 8, 32]),
    br=st.sampled_from([0, 1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_stats(r, m, br, seed):
    if br and r % br:
        br = 1
    x = _x(r, m, seed=seed % 100_000)
    got = stats.calib_stats(x, br=br)
    want = ref.calib_stats(x)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4)
