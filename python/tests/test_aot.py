"""AOT path tests: manifest integrity and HLO-text round-trip sanity.

These run against the committed lowering logic (not the artifacts dir, which
is a build output): they lower the micro kernels fresh and verify the text is
parseable-looking HLO with the right entry signature; full load-and-execute
verification happens on the Rust side (runtime integration tests).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import CONFIGS


def test_to_hlo_text_roundtrip_simple():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[2,2]" in text
    # return_tuple=True -> root is a tuple
    assert "(f32[2,2]" in text


def test_micro_emitter(tmp_path):
    em = aot.Emitter(str(tmp_path))
    aot.lower_micro(em)
    names = [r["name"] for r in em.records]
    assert "qlinear.m64k128n96r8" in names
    for r in em.records:
        p = tmp_path / r["file"]
        assert p.exists() and p.stat().st_size > 100
        head = p.read_text()[:200]
        assert "HloModule" in head
        assert r["inputs"] and r["outputs"]


def test_lm_fwd_lowering_contains_pallas_loop(tmp_path):
    """The interpret-mode pallas attention lowers into the same module —
    the three-layer contract (L1 inside L2's HLO)."""
    cfg = CONFIGS["micro"]
    em = aot.Emitter(str(tmp_path))
    pspecs = aot._param_specs(cfg)
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    import functools

    em.emit("lm_fwd.micro", functools.partial(model.lm_fwd, cfg), [tok] + pspecs,
            ["tokens"] + [n for n, _ in cfg.param_layout()], ["logits"], "micro")
    text = (tmp_path / "lm_fwd.micro.hlo.txt").read_text()
    assert "HloModule" in text
    # grid loop of the interpret-mode kernel shows up as a while/call structure
    assert ("while" in text) or ("call" in text)


def test_manifest_schema(tmp_path):
    em = aot.Emitter(str(tmp_path))
    aot.lower_micro(em)
    manifest = {"version": 1, "configs": {}, "artifacts": em.records}
    s = json.dumps(manifest)
    back = json.loads(s)
    for r in back["artifacts"]:
        assert set(r) >= {"name", "file", "config", "inputs", "outputs", "sha256"}
        for io in r["inputs"] + r["outputs"]:
            assert set(io) == {"name", "dtype", "shape"}


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_consistent():
    """If `make artifacts` has run, the manifest must match the configs."""
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    for cname, meta in man["configs"].items():
        cfg = CONFIGS[cname]
        assert meta["n_params"] == cfg.n_params()
        assert [(n, tuple(s)) for n, s in meta["param_layout"]] == cfg.param_layout()
    for r in man["artifacts"]:
        assert os.path.exists(os.path.join(root, r["file"])), r["name"]
