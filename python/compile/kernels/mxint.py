"""Pallas MXINT quantize-dequantize kernel (L1).

The hot loop of the quantization *pipeline*: every weight matrix (and, in the
emulated-quantization ablations, activations) passes through this kernel.  On
TPU the natural mapping is: one VMEM-resident tile of shared-exponent groups
per grid step, the absmax reduction and rescale staying entirely in VREGs —
the block layout below expresses exactly that schedule with a BlockSpec.

CPU note: lowered with ``interpret=True`` (the image's PJRT CPU client cannot
run Mosaic custom calls), so the grid executes as a sequential loop of fused
elementwise ops — numerically identical to the TPU path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mxint_kernel(x_ref, o_ref, *, bits: int):
    """One grid step: a (rows_per_step, block_size) tile = rows of groups."""
    from .ref import floor_log2

    v = x_ref[...]
    amax = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    safe = jnp.where(amax > 0, amax, 1.0)
    e = floor_log2(safe)
    scale = jnp.exp2((e - (bits - 2)).astype(jnp.float32))
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(jnp.round(v / scale), -qmax, qmax)
    o_ref[...] = jnp.where(amax > 0, q * scale, 0.0).astype(o_ref.dtype)


def mxint_qdq(x, bits: int, block_size: int, rows_per_step: int = 0, interpret: bool = True):
    """Quantize-dequantize `x` with a shared exponent per `block_size` group.

    Groups run along the last axis; `x.shape[-1]` must divide evenly.
    `rows_per_step` controls the grid granularity (0 = whole array in one
    step, the layout used for CPU artifacts; tests sweep multi-step grids).
    """
    assert bits >= 2, bits
    shape = x.shape
    assert shape[-1] % block_size == 0, (shape, block_size)
    g = x.reshape(-1, block_size)
    rows = g.shape[0]
    if rows_per_step <= 0 or rows_per_step > rows:
        rows_per_step = rows
    assert rows % rows_per_step == 0, (rows, rows_per_step)

    out = pl.pallas_call(
        functools.partial(_mxint_kernel, bits=bits),
        grid=(rows // rows_per_step,),
        in_specs=[pl.BlockSpec((rows_per_step, block_size), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows_per_step, block_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g.shape, x.dtype),
        interpret=interpret,
    )(g)
    return out.reshape(shape)
