"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must match
its oracle bit-for-bit (quantizers) or to tight fp tolerance (matmuls,
attention).  The Rust `quant` module mirrors the same formulas; the pytest
suite pins both sides to these definitions.
"""

import jax.numpy as jnp
import jax


# ----------------------------------------------------------------------------
# MXINT block quantization (shared-exponent integer, OCP MX-style).
#
# A block of `block_size` consecutive elements (along the last axis) shares an
# 8-bit exponent e = floor(log2(max|v|)); each element is a `bits`-bit
# two's-complement integer q with value q * 2^(e - bits + 2), i.e. the scale
# places the block maximum just below 2^(bits-1).  Average bits/element:
# bits + 8/block_size  (4.25 for bits=4,bs=32; 3.25 for 3/32; 2.50 for 2/16).
#
# Rounding is round-half-to-even to match both jnp.round and Rust's
# f32::round_ties_even.
# ----------------------------------------------------------------------------


def floor_log2(x):
    """Exact floor(log2(x)) for positive f32 via exponent-bit extraction.

    Bit-identical across JAX/XLA and the Rust mirror (a libm `log2` call
    could round differently at values just below powers of two).  Subnormal
    inputs clamp to -126.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    return jnp.maximum(e, -126)


def mxint_qdq(x, bits: int, block_size: int):
    """Quantize-dequantize `x` (last axis grouped by `block_size`)."""
    assert bits >= 2
    shape = x.shape
    assert shape[-1] % block_size == 0, (shape, block_size)
    g = x.reshape(-1, block_size)
    amax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    safe = jnp.where(amax > 0, amax, 1.0)
    e = floor_log2(safe)
    scale = jnp.exp2((e - (bits - 2)).astype(jnp.float32))
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax)
    out = jnp.where(amax > 0, q * scale, 0.0)
    return out.reshape(shape).astype(x.dtype)


# ----------------------------------------------------------------------------
# Quantized linear with low-rank reconstruction: y = x @ w + (x @ a) @ b.
# `w` is the *dequantized* weight (the artifact takes it as a runtime input so
# one HLO serves every quantization method); a/b are the rank-k terms.
# ----------------------------------------------------------------------------


def qlinear_lowrank(x, w, a, b):
    return x @ w + (x @ a) @ b


# ----------------------------------------------------------------------------
# Causal softmax attention, layout [T, S, hd] with T = batch * heads.
# ----------------------------------------------------------------------------


def causal_attention(q, k, v, scale: float):
    s = q.shape[-2]
    logits = (q @ jnp.swapaxes(k, -1, -2)) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask, logits, jnp.float32(-1e30))
    p = jax.nn.softmax(logits, axis=-1)
    return p @ v


# ----------------------------------------------------------------------------
# Calibration statistics over the row axis of x [R, m]:
# per-dim sum of squares, per-dim sum of |x|, and the raw autocorrelation
# accumulator X^T X.  The Rust coordinator divides by the row count and
# accumulates across batches in f64.
# ----------------------------------------------------------------------------


def calib_stats(x):
    sumsq = jnp.sum(x * x, axis=0)
    sumabs = jnp.sum(jnp.abs(x), axis=0)
    rxx = x.T @ x
    return sumsq, sumabs, rxx
