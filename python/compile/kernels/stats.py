"""Pallas calibration-statistics kernel (L1).

Computes, over the row axis of x [R, m]:
    sumsq[m]  = sum_r x[r,:]^2
    sumabs[m] = sum_r |x[r,:]|
    rxx[m,m]  = X^T X
in row blocks, accumulating into the outputs across sequential grid steps
(the canonical reduction-into-output Pallas pattern: outputs use a constant
index map, the first step initializes, later steps accumulate).

On TPU the (br, m) stripe and the (m, m) accumulator live in VMEM; the
rank-1(-batched) update X_b^T X_b is an MXU op.  The Rust coordinator calls
this artifact per calibration batch and folds the f32 partials into its f64
running accumulators (the paper's App. A.7 numeric-stability recipe).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(x_ref, sq_ref, ab_ref, rxx_ref):
    i = pl.program_id(0)
    x = x_ref[...]  # (br, m)
    sq = jnp.sum(x * x, axis=0)
    ab = jnp.sum(jnp.abs(x), axis=0)
    rxx = jnp.dot(x.T, x, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        sq_ref[...] = sq
        ab_ref[...] = ab
        rxx_ref[...] = rxx

    @pl.when(i > 0)
    def _acc():
        sq_ref[...] += sq
        ab_ref[...] += ab
        rxx_ref[...] += rxx


def calib_stats(x, br: int = 0, interpret: bool = True):
    """Return (sumsq[m], sumabs[m], rxx[m,m]) accumulated over rows of x."""
    r, m = x.shape
    br = r if br <= 0 or br > r else br
    assert r % br == 0, (r, br)

    return pl.pallas_call(
        _stats_kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, m), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m, m), jnp.float32),
        ),
        interpret=interpret,
    )(x)
