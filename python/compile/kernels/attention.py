"""Pallas causal-attention kernel (L1), flash-style query blocking.

Grid: (batch*heads, S/bq).  Each step holds one (bq, hd) query tile plus the
full (S, hd) K/V panels in VMEM (S <= 128 for every config in this repo, so
the panels fit comfortably; for longer contexts the K loop would move into
the grid with an online-softmax accumulator).  The causal mask is generated
in-kernel from the block's absolute row offset.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, bq: int):
    j = pl.program_id(1)
    q = q_ref[0]  # (bq, hd)
    k = k_ref[0]  # (S, hd)
    v = v_ref[0]  # (S, hd)
    s = k.shape[0]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, S)
    rows = j * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, s), 1)
    logits = jnp.where(cols <= rows, logits, jnp.float32(-1e30))
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def causal_attention(q, k, v, scale: float, bq: int = 0, interpret: bool = True):
    """Causal softmax attention over [T, S, hd] (T = batch * heads)."""
    t, s, hd = q.shape
    assert k.shape == (t, s, hd) and v.shape == (t, s, hd)
    bq = s if bq <= 0 or bq > s else bq
    assert s % bq == 0, (s, bq)

    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, bq=bq),
        grid=(t, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((t, s, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
