"""Pallas fused quantized-linear + low-rank-reconstruction kernel (L1).

This is the paper's inference hot-spot: ``y = x @ W~ + (x @ A_k) @ B_k``.
The whole point of quantization error reconstruction is that the rank-k
correction rides along the main matmul at ~2k/n extra MXU work; this kernel
expresses that fusion explicitly.

TPU mapping (see DESIGN.md §Hardware-Adaptation): grid tiles (M/bm, N/bn);
each step keeps an (bm, K) activation stripe and a (K, bn) weight tile in
VMEM, issues the main MXU matmul, then the two skinny rank-k matmuls whose
(bm, k) intermediate never leaves VMEM.  The GPU papers' threadblock/WMMA
scheduling becomes the BlockSpec index maps below.

CPU note: lowered with ``interpret=True``; with a (1,1) grid this is exactly
the fused jnp expression, so the artifact hot path pays no interpret-mode
grid overhead.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qlinear_kernel(x_ref, w_ref, a_ref, b_ref, o_ref):
    x = x_ref[...]  # (bm, K)
    w = w_ref[...]  # (K, bn)
    a = a_ref[...]  # (K, r)
    b = b_ref[...]  # (r, bn)
    t = jnp.dot(x, a, preferred_element_type=jnp.float32)  # (bm, r) — VMEM-resident
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = (y + jnp.dot(t, b, preferred_element_type=jnp.float32)).astype(o_ref.dtype)


def qlinear_lowrank(x, w, a, b, bm: int = 0, bn: int = 0, interpret: bool = True):
    """``x @ w + (x @ a) @ b`` tiled over (M, N).

    x: [M, K], w: [K, N], a: [K, r], b: [r, N] -> [M, N].
    bm/bn = 0 selects whole-axis blocks (the CPU-artifact layout).
    """
    m, k = x.shape
    k2, n = w.shape
    r = a.shape[1]
    assert k == k2 and a.shape[0] == k and b.shape == (r, n), (x.shape, w.shape, a.shape, b.shape)
    bm = m if bm <= 0 or bm > m else bm
    bn = n if bn <= 0 or bn > n else bn
    assert m % bm == 0 and n % bn == 0, (m, bm, n, bn)

    return pl.pallas_call(
        _qlinear_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, a, b)
