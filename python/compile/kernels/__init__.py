"""L1 Pallas kernels: MXINT quant-dequant, fused low-rank qlinear,
flash-style causal attention, calibration statistics.  Each has a pure-jnp
oracle in :mod:`compile.kernels.ref`."""

from . import attention, mxint, qlinear, ref, stats  # noqa: F401
