"""AOT lowering: JAX entry points -> HLO text artifacts + manifest.json.

Run once at build time (``make artifacts``); the Rust coordinator loads the
artifacts through the PJRT C API and Python never appears on the request
path.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  Entry computations are lowered with
``return_tuple=True``; the Rust side unwraps the tuple.

Usage:
    python -m compile.aot --out-dir ../artifacts [--configs nano,small]
"""

import argparse
import functools
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, DEFAULT_AOT_CONFIGS, ModelConfig

# LoRA ranks lowered per config.  "lm" feeds QPEFT LM steps (Table 2 / 7 / 8),
# "cls" feeds the GLUE-like suite (Tables 1 / 9 / 10), "fwd_lr" is the
# serving-form forward that keeps A/B separate (no-overhead bench).
RANK_SETS = {
    "nano": dict(lm=(4, 8), cls=(4, 8), fwd_lr=(8,)),
    "small": dict(lm=(8, 16, 32), cls=(4, 8, 12, 16, 20, 32), fwd_lr=(32,)),
    "base": dict(lm=(8,), cls=(8,), fwd_lr=(32,)),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(cfg: ModelConfig):
    return [_spec(s) for _, s in cfg.param_layout()]


def _lora_specs(cfg: ModelConfig, rank: int):
    return [_spec(s) for _, s in cfg.lora_layout(rank)]


def _io_list(specs, names):
    out = []
    for name, s in zip(names, specs):
        out.append({"name": name, "dtype": str(s.dtype), "shape": list(s.shape)})
    return out


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.records = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, in_specs, in_names, out_names, cfg_name, meta=None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = name + ".hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *in_specs)
        rec = {
            "name": name,
            "file": fname,
            "config": cfg_name,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "inputs": _io_list(in_specs, in_names),
            "outputs": _io_list(list(out_shapes), out_names),
        }
        if meta:
            rec.update(meta)
        self.records.append(rec)
        print(f"  {name:<36s} {len(text)/1e6:7.2f} MB  {time.time()-t0:6.1f}s", flush=True)


def lower_config(em: Emitter, cfg: ModelConfig, ranks):
    b, s = cfg.batch, cfg.seq
    tok = _spec((b, s), jnp.int32)
    tgt = _spec((b, s), jnp.int32)
    lab = _spec((b,), jnp.int32)
    pspecs = _param_specs(cfg)
    pnames = [n for n, _ in cfg.param_layout()]
    c = cfg.name

    em.emit(f"lm_fwd.{c}", functools.partial(model.lm_fwd, cfg),
            [tok] + pspecs, ["tokens"] + pnames, ["logits"], c)
    em.emit(f"lm_nll.{c}", functools.partial(model.lm_nll, cfg),
            [tok, tgt] + pspecs, ["tokens", "targets"] + pnames, ["nll"], c)
    em.emit(f"lm_logits_last.{c}", functools.partial(model.lm_logits_last, cfg),
            [tok] + pspecs, ["tokens"] + pnames, ["logits_last"], c)
    tap_names = [n for n, _ in cfg.tap_layout()]
    em.emit(f"lm_fwd_taps.{c}", functools.partial(model.lm_fwd_taps, cfg),
            [tok] + pspecs, ["tokens"] + pnames, ["logits"] + tap_names, c)
    em.emit(f"lm_pool.{c}", functools.partial(model.lm_pool, cfg),
            [tok] + pspecs, ["tokens"] + pnames, ["pooled"], c)
    em.emit(f"pretrain_step.{c}", functools.partial(model.pretrain_step, cfg),
            [tok, tgt] + pspecs, ["tokens", "targets"] + pnames,
            ["loss"] + ["g." + n for n in pnames], c)

    head_specs = [_spec((cfg.d_model, cfg.n_classes)), _spec((cfg.n_classes,))]
    head_names = ["head_w", "head_b"]
    em.emit(f"full_cls_step.{c}", functools.partial(model.full_cls_step, cfg),
            [tok, lab] + pspecs + head_specs,
            ["tokens", "labels"] + pnames + head_names,
            ["loss"] + ["g." + n for n in pnames] + ["g.head_w", "g.head_b"], c)
    em.emit(f"cls_fwd.{c}.r0", functools.partial(model.cls_fwd, cfg, 0),
            [tok] + pspecs + head_specs, ["tokens"] + pnames + head_names,
            ["cls_logits"], c, meta={"rank": 0})

    for r in ranks["fwd_lr"]:
        lspecs = _lora_specs(cfg, r)
        lnames = [n for n, _ in cfg.lora_layout(r)]

        def fwd_lr(tokens, *flat, _r=r):
            base = list(flat[: len(pspecs)])
            lora = list(flat[len(pspecs):])
            logits, _ = model.lm_logits(cfg, base, tokens, lora=lora, rank=_r)
            return (logits,)

        em.emit(f"lm_fwd_lr.{c}.r{r}", fwd_lr, [tok] + pspecs + lspecs,
                ["tokens"] + pnames + lnames, ["logits"], c, meta={"rank": r})

    for r in ranks["lm"]:
        lspecs = _lora_specs(cfg, r)
        lnames = [n for n, _ in cfg.lora_layout(r)]
        em.emit(f"lora_lm_step.{c}.r{r}", functools.partial(model.lora_lm_step, cfg, r),
                [tok, tgt] + pspecs + lspecs,
                ["tokens", "targets"] + pnames + lnames,
                ["loss"] + ["g." + n for n in lnames], c, meta={"rank": r})

    for r in ranks["cls"]:
        lspecs = _lora_specs(cfg, r)
        lnames = [n for n, _ in cfg.lora_layout(r)]
        em.emit(f"lora_cls_step.{c}.r{r}", functools.partial(model.lora_cls_step, cfg, r),
                [tok, lab] + pspecs + lspecs + head_specs,
                ["tokens", "labels"] + pnames + lnames + head_names,
                ["loss"] + ["g." + n for n in lnames] + ["g.head_w", "g.head_b"],
                c, meta={"rank": r})
        em.emit(f"cls_fwd.{c}.r{r}", functools.partial(model.cls_fwd, cfg, r),
                [tok] + pspecs + lspecs + head_specs,
                ["tokens"] + pnames + lnames + head_names,
                ["cls_logits"], c, meta={"rank": r})


def lower_micro(em: Emitter):
    """Standalone kernel artifacts for runtime unit tests and microbenches."""
    from .kernels import mxint, qlinear, stats

    m, k, n, r = 64, 128, 96, 8
    em.emit("qlinear.m64k128n96r8",
            lambda x, w, a, b: (qlinear.qlinear_lowrank(x, w, a, b),),
            [_spec((m, k)), _spec((k, n)), _spec((k, r)), _spec((r, n))],
            ["x", "w", "a", "b"], ["y"], "micro")
    em.emit("mxint_qdq.b4s32",
            lambda x: (mxint.mxint_qdq(x, 4, 32),),
            [_spec((64, 128))], ["x"], ["y"], "micro")
    em.emit("calib_stats.m128",
            lambda x: stats.calib_stats(x),
            [_spec((256, 128))], ["x"], ["sumsq", "sumabs", "rxx"], "micro")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_AOT_CONFIGS))
    args = ap.parse_args()

    names = [c for c in args.configs.split(",") if c]
    em = Emitter(args.out_dir)
    t0 = time.time()
    lower_micro(em)
    cfg_meta = {}
    for cname in names:
        cfg = CONFIGS[cname]
        print(f"config {cname}: {cfg.n_params()/1e6:.2f}M params", flush=True)
        ranks = RANK_SETS[cname]
        lower_config(em, cfg, ranks)
        cfg_meta[cname] = {
            **cfg.to_dict(),
            "head_dim": cfg.head_dim,
            "n_params": cfg.n_params(),
            "param_layout": [[n, list(s)] for n, s in cfg.param_layout()],
            "tap_layout": [[n, list(s)] for n, s in cfg.tap_layout()],
            "rank_sets": {k: list(v) for k, v in ranks.items()},
        }

    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "configs": cfg_meta,
        "artifacts": em.records,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(em.records)} artifacts in {time.time()-t0:.1f}s -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
