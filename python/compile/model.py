"""L2: the JAX transformer (forward, calibration taps, training steps).

Every public entry point here is lowered once by :mod:`compile.aot` to HLO
text and executed from the Rust coordinator; Python never runs at request
time.  Parameters are a flat *list* of arrays in the canonical order defined
by :meth:`compile.configs.ModelConfig.param_layout` so the positional HLO
argument order is deterministic for the Rust side.

The linear layers call the L1 Pallas kernels (``use_pallas=True``, the
default for lowering) so the kernels lower into the same HLO module; the
pure-jnp path (``use_pallas=False``) is the oracle used by the pytest suite.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import LINEAR_SITES, ModelConfig
from .kernels import attention as attn_k
from .kernels import qlinear as qlin_k


# ----------------------------------------------------------------------------
# Initialization (python-side; the Rust model/init.rs mirrors the same scheme
# for checkpoints it creates itself).
# ----------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> list:
    """GPT-2-style init: N(0, 0.02) embeddings/weights, ones/zeros for LN."""
    params = []
    for name, shape in cfg.param_layout():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1_g", "ln2_g", "lnf_g")):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("ln1_b", "ln2_b", "lnf_b")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            std = 0.02
            if name.endswith(("wo", "w_down")):  # residual-branch scaling
                std = 0.02 / (2 * cfg.n_layers) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def zero_lora(cfg: ModelConfig, rank: int) -> list:
    return [jnp.zeros(shape, jnp.float32) for _, shape in cfg.lora_layout(rank)]


# ----------------------------------------------------------------------------
# Forward pass.
# ----------------------------------------------------------------------------


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _linear(x2d, w, a, b, use_pallas):
    """x2d: [T, m] @ w [m, n] + rank-k correction (a: [m,r], b: [r,n])."""
    if use_pallas:
        return qlin_k.qlinear_lowrank(x2d, w, a, b)
    return x2d @ w + (x2d @ a) @ b


def _unpack(cfg: ModelConfig, params):
    it = iter(params)
    embed, pos = next(it), next(it)
    blocks = []
    for _ in range(cfg.n_layers):
        blocks.append(
            dict(
                ln1_g=next(it), ln1_b=next(it),
                wq=next(it), wk=next(it), wv=next(it), wo=next(it),
                ln2_g=next(it), ln2_b=next(it),
                w_up=next(it), w_down=next(it),
            )
        )
    lnf_g, lnf_b = next(it), next(it)
    rest = list(it)
    assert not rest, f"{len(rest)} unconsumed params"
    return embed, pos, blocks, lnf_g, lnf_b


def _unpack_lora(cfg: ModelConfig, lora, rank: int):
    """-> per-block dict site -> (A, B); `lora=None` yields zero adapters."""
    if lora is None:
        return None
    it = iter(lora)
    out = []
    for _ in range(cfg.n_layers):
        d = {}
        for site in LINEAR_SITES:
            a = next(it)
            b = next(it)
            d[site] = (a, b)
        out.append(d)
    assert not list(it)
    return out


def lm_hidden(cfg: ModelConfig, params, tokens, lora=None, rank: int = 0,
              use_pallas: bool = True, collect_taps: bool = False):
    """Run the trunk; returns (final hidden [B,S,D], taps list)."""
    embed, pos, blocks, lnf_g, lnf_b = _unpack(cfg, params)
    adapters = _unpack_lora(cfg, lora, rank)
    bsz, s = tokens.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    scale = 1.0 / (hd ** 0.5)

    x = embed[tokens] + pos[None, :s, :]
    taps = []

    def lin(site, blk_i, x3d, w):
        t = x3d.reshape(-1, x3d.shape[-1])
        if adapters is None:
            y = t @ w
        else:
            a, b = adapters[blk_i][site]
            y = _linear(t, w, a, b, use_pallas)
        return y.reshape(x3d.shape[0], x3d.shape[1], -1)

    for i, blk in enumerate(blocks):
        h_in = _layernorm(x, blk["ln1_g"], blk["ln1_b"])
        if collect_taps:
            taps.append(h_in)  # attn_in
        q = lin("wq", i, h_in, blk["wq"])
        k = lin("wk", i, h_in, blk["wk"])
        v = lin("wv", i, h_in, blk["wv"])
        # [B,S,D] -> [B*H, S, hd]
        def split(t):
            return t.reshape(bsz, s, h, hd).transpose(0, 2, 1, 3).reshape(bsz * h, s, hd)
        if use_pallas:
            o = attn_k.causal_attention(split(q), split(k), split(v), scale)
        else:
            from .kernels import ref
            o = ref.causal_attention(split(q), split(k), split(v), scale)
        o = o.reshape(bsz, h, s, hd).transpose(0, 2, 1, 3).reshape(bsz, s, d)
        if collect_taps:
            taps.append(o)  # o_in
        x = x + lin("wo", i, o, blk["wo"])

        m_in = _layernorm(x, blk["ln2_g"], blk["ln2_b"])
        if collect_taps:
            taps.append(m_in)  # mlp_in
        u = lin("w_up", i, m_in, blk["w_up"])
        u = jax.nn.gelu(u, approximate=True)
        if collect_taps:
            taps.append(u)  # mlp_mid
        x = x + lin("w_down", i, u, blk["w_down"])

    x = _layernorm(x, lnf_g, lnf_b)
    return x, taps


def lm_logits(cfg: ModelConfig, params, tokens, **kw):
    hid, taps = lm_hidden(cfg, params, tokens, **kw)
    embed = params[0]
    return hid @ embed.T, taps


def _nll(logits, targets):
    """Per-token negative log-likelihood [B,S] from logits [B,S,V]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - gold


# ----------------------------------------------------------------------------
# Entry points lowered by aot.py.  All take (and return) flat tuples.
# ----------------------------------------------------------------------------


def lm_fwd(cfg: ModelConfig, tokens, *params):
    """tokens [B,S] i32 -> logits [B,S,V]."""
    logits, _ = lm_logits(cfg, list(params), tokens)
    return (logits,)


def lm_nll(cfg: ModelConfig, tokens, targets, *params):
    """-> per-token NLL [B,S] (small transfer for the ppl evaluator)."""
    logits, _ = lm_logits(cfg, list(params), tokens)
    return (_nll(logits, targets),)


def lm_logits_last(cfg: ModelConfig, tokens, *params):
    """-> logits of the final position only [B,V] (decode/serving)."""
    logits, _ = lm_logits(cfg, list(params), tokens)
    return (logits[:, -1, :],)


def lm_pool(cfg: ModelConfig, tokens, *params):
    """-> mean-pooled final hidden state [B, D] (feature extractor for the
    Table-4 linear-probe evaluation)."""
    hid, _ = lm_hidden(cfg, list(params), tokens)
    return (jnp.mean(hid, axis=1),)


def lm_fwd_taps(cfg: ModelConfig, tokens, *params):
    """-> (logits, 4*L calibration taps) — the calibration artifact."""
    logits, taps = lm_logits(cfg, list(params), tokens, collect_taps=True)
    return (logits, *taps)


def _split_base_lora(cfg: ModelConfig, rank: int, flat):
    n_base = len(cfg.param_layout())
    base = list(flat[:n_base])
    lora = list(flat[n_base:])
    assert len(lora) == len(cfg.lora_layout(rank)), (len(lora), rank)
    return base, lora


def lora_lm_step(cfg: ModelConfig, rank: int, tokens, targets, *flat):
    """QPEFT language-modeling step.

    flat = base params (frozen, typically dequantized W~) ++ LoRA tensors.
    -> (loss, *grads_wrt_lora).  The Rust optimizer applies the update.
    """
    base, lora = _split_base_lora(cfg, rank, flat)

    def loss_fn(lora_list):
        # use_pallas=False: pallas_call has no autodiff rule; the jnp oracle
        # is numerically identical and fully differentiable.
        logits, _ = lm_logits(cfg, base, tokens, lora=lora_list, rank=rank, use_pallas=False)
        return jnp.mean(_nll(logits, targets))

    loss, grads = jax.value_and_grad(loss_fn)(lora)
    return (loss, *grads)


def cls_logits(cfg: ModelConfig, params, tokens, lora, rank, head_w, head_b,
               use_pallas: bool = True):
    hid, _ = lm_hidden(cfg, params, tokens, lora=lora, rank=rank, use_pallas=use_pallas)
    pooled = jnp.mean(hid, axis=1)  # [B, D]
    return pooled @ head_w + head_b


def lora_cls_step(cfg: ModelConfig, rank: int, tokens, labels, *flat):
    """GLUE-style classification step.

    flat = base ++ lora ++ (head_w [D,C], head_b [C]).
    -> (loss, *grads_lora, grad_head_w, grad_head_b).
    """
    n_base = len(cfg.param_layout())
    base = list(flat[:n_base])
    lora = list(flat[n_base:-2])
    head_w, head_b = flat[-2], flat[-1]
    assert len(lora) == len(cfg.lora_layout(rank))

    def loss_fn(train):
        lora_l, hw, hb = train
        logits = cls_logits(cfg, base, tokens, lora_l, rank, hw, hb, use_pallas=False)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    loss, (g_lora, g_hw, g_hb) = jax.value_and_grad(loss_fn)((lora, head_w, head_b))
    return (loss, *g_lora, g_hw, g_hb)


def full_cls_step(cfg: ModelConfig, tokens, labels, *flat):
    """Full fine-tuning baseline (Table 1 "Full FT"): grads w.r.t. every base
    parameter plus the classifier head.  flat = base ++ (head_w, head_b)."""
    n_base = len(cfg.param_layout())
    base = list(flat[:n_base])
    head_w, head_b = flat[-2], flat[-1]

    def loss_fn(train):
        plist, hw, hb = train
        logits = cls_logits(cfg, plist, tokens, None, 0, hw, hb, use_pallas=False)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    loss, (g_base, g_hw, g_hb) = jax.value_and_grad(loss_fn)((base, head_w, head_b))
    return (loss, *g_base, g_hw, g_hb)


def cls_fwd(cfg: ModelConfig, rank: int, tokens, *flat):
    """-> class logits [B,C] for evaluation of the fine-tuned classifier."""
    n_base = len(cfg.param_layout())
    base = list(flat[:n_base])
    lora = list(flat[n_base:-2])
    head_w, head_b = flat[-2], flat[-1]
    if not lora:
        lora = None
    return (cls_logits(cfg, base, tokens, lora, rank, head_w, head_b),)


def pretrain_step(cfg: ModelConfig, tokens, targets, *params):
    """Full-parameter LM step -> (loss, *grads).  Used by the Rust trainer
    to pretrain the experiment subject models from scratch."""

    def loss_fn(plist):
        logits, _ = lm_logits(cfg, plist, tokens, use_pallas=False)
        return jnp.mean(_nll(logits, targets))

    loss, grads = jax.value_and_grad(loss_fn)(list(params))
    return (loss, *grads)


# convenience: jitted oracle used by python tests
def ref_lm_fwd(cfg: ModelConfig, params, tokens):
    logits, _ = lm_logits(cfg, params, tokens, use_pallas=False)
    return logits
