"""Model configurations shared between the L2 compile path and the L3 runtime.

The Rust coordinator never imports this module; it reads the same facts from
``artifacts/manifest.json`` which :mod:`compile.aot` emits.  Keep this file
dependency-free (no jax import) so tests can import it cheaply.

Canonical parameter layout (order matters — it is the positional argument
order of every lowered HLO entry point):

    0: embed      [V, D]      token embedding (tied LM head)
    1: pos_embed  [S, D]      learned positional embedding
    per block i in 0..L:
        ln1_g [D], ln1_b [D],
        wq [D, D], wk [D, D], wv [D, D], wo [D, D],
        ln2_g [D], ln2_b [D],
        w_up [D, F], w_down [F, D]
    then: lnf_g [D], lnf_b [D]

LoRA adapter layout (order of the trainable arguments of ``lora_*_step``):

    per block i in 0..L, per site in (wq, wk, wv, wo, w_up, w_down):
        A [in_dim, r], B [r, out_dim]

Calibration tap sites per block (inputs of the quantized linears):

    attn_in  [B, S, D]   input of wq / wk / wv   (post-ln1)
    o_in     [B, S, D]   input of wo
    mlp_in   [B, S, D]   input of w_up           (post-ln2)
    mlp_mid  [B, S, F]   input of w_down
"""

from dataclasses import dataclass, field, asdict


LINEAR_SITES = ("wq", "wk", "wv", "wo", "w_up", "w_down")

# tap site feeding each linear site
SITE_TAP = {
    "wq": "attn_in",
    "wk": "attn_in",
    "wv": "attn_in",
    "wo": "o_in",
    "w_up": "mlp_in",
    "w_down": "mlp_mid",
}

TAP_SITES = ("attn_in", "o_in", "mlp_in", "mlp_mid")


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int  # static batch size baked into the artifacts
    n_classes: int = 8  # classifier head width for the GLUE-like suite

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def linear_shape(self, site: str):
        d, f = self.d_model, self.d_ff
        return {
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "w_up": (d, f),
            "w_down": (f, d),
        }[site]

    def param_layout(self):
        """Ordered (name, shape) list matching the HLO argument order."""
        v, d, f, s = self.vocab, self.d_model, self.d_ff, self.seq
        out = [("embed", (v, d)), ("pos_embed", (s, d))]
        for i in range(self.n_layers):
            p = f"blk{i}."
            out += [
                (p + "ln1_g", (d,)),
                (p + "ln1_b", (d,)),
                (p + "wq", (d, d)),
                (p + "wk", (d, d)),
                (p + "wv", (d, d)),
                (p + "wo", (d, d)),
                (p + "ln2_g", (d,)),
                (p + "ln2_b", (d,)),
                (p + "w_up", (d, f)),
                (p + "w_down", (f, d)),
            ]
        out += [("lnf_g", (d,)), ("lnf_b", (d,))]
        return out

    def lora_layout(self, rank: int):
        """Ordered (name, shape) list of LoRA adapter tensors."""
        out = []
        for i in range(self.n_layers):
            for site in LINEAR_SITES:
                m, n = self.linear_shape(site)
                out.append((f"blk{i}.{site}.A", (m, rank)))
                out.append((f"blk{i}.{site}.B", (rank, n)))
        return out

    def tap_layout(self):
        """Ordered (name, shape) list of calibration taps of lm_fwd_taps."""
        b, s, d, f = self.batch, self.seq, self.d_model, self.d_ff
        shp = {"attn_in": (b, s, d), "o_in": (b, s, d), "mlp_in": (b, s, d), "mlp_mid": (b, s, f)}
        out = []
        for i in range(self.n_layers):
            for t in TAP_SITES:
                out.append((f"blk{i}.{t}", shp[t]))
        return out

    def n_params(self) -> int:
        return sum(int_prod(s) for _, s in self.param_layout())

    def to_dict(self):
        return asdict(self)


def int_prod(shape):
    p = 1
    for s in shape:
        p *= int(s)
    return p


# ----------------------------------------------------------------------------
# Registry.  `micro` is for kernel/unit tests only (never lowered), `nano`
# drives fast integration tests, `small` is the main experiment subject
# (the "RoBERTa/TinyLlama stand-in"), `base` the scale point.
# ----------------------------------------------------------------------------

CONFIGS = {
    "micro": ModelConfig("micro", vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq=16, batch=2),
    "nano": ModelConfig("nano", vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=256, seq=64, batch=4),
    "small": ModelConfig("small", vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=512, seq=128, batch=8),
    "base": ModelConfig("base", vocab=1024, d_model=256, n_layers=6, n_heads=8, d_ff=1024, seq=128, batch=4),
}

DEFAULT_AOT_CONFIGS = ("nano", "small")
